"""Paged KV cache: fixed-size block pool + free list + block tables.

Storage for the attention KV leaves of a serving cache. Instead of one
dense ``(lead, R, T, KV, Dh)`` tensor, each KV leaf lives in a pool of
``block_size``-token blocks ``(lead, num_blocks, block_size, KV, Dh)``
and each request slot owns an ordered *block table* of pool-block ids.
Admission allocates a table from the free list; retiring (or preempting)
a request returns its blocks, so memory follows live requests rather
than the worst-case batch — the point of paged attention serving.

Block 0 is reserved as the *null block*: inactive request slots keep
their table pointed at it, so gathers/scatters over the full fixed slot
axis stay shape-static (no recompiles as requests join and retire) and
garbage written through inactive slots lands harmlessly in block 0.

The logical per-request view is a ring buffer of ``view_len`` tokens
(``models/layers`` slot convention: position ``length % view_len`` holds
the newest token), so a view shorter than the longest sequence gives
sliding-window serving, and a block being overwritten after wrap is the
eviction/refill case the tests exercise.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def gather_views(pools, tables, block_size: int):
    """Pure form of :meth:`PagedKV.gather` (jit-friendly).

    pools: leaf -> (lead, NB, bs, ...); tables: (R, nb) int32.
    Returns leaf -> (R, lead, 1, nb*bs, ...): the dense per-request views,
    request axis leading so the result vmaps directly over slots.
    """
    out = {}
    R, nb = tables.shape
    for name, pool in pools.items():
        v = pool[:, tables]                        # (lead, R, nb, bs, ...)
        v = jnp.moveaxis(v, 1, 0)                  # (R, lead, nb, bs, ...)
        lead = v.shape[1]
        v = v.reshape(R, lead, nb * block_size, *v.shape[4:])
        out[name] = v[:, :, None]                  # (R, lead, 1, T, ...)
    return out


def scatter_tokens(pools, tables, views, positions, block_size: int):
    """Pure form of :meth:`PagedKV.scatter_token` (jit-friendly).

    Writes back the single view slot each request just filled and returns
    the new pools. ``positions`` is the ``(R,)`` ring slot written
    (``old_length % view_len``). Live block tables are disjoint, so the
    scatter has no collisions; inactive slots target the null block,
    whose contents are never read as valid.
    """
    R = tables.shape[0]
    pos = jnp.asarray(positions, jnp.int32)
    blk = tables[jnp.arange(R), pos // block_size]      # (R,)
    off = pos % block_size                              # (R,)
    new_pools = {}
    for name, view in views.items():
        # written token per request: (R, lead, ...)
        vals = view[jnp.arange(R), :, 0, pos]
        vals = jnp.moveaxis(vals, 0, 1)                 # (lead, R, ...)
        new_pools[name] = pools[name].at[:, blk, off].set(vals)
    return new_pools


class BlockPool:
    """Host-side free list over ``num_blocks`` pool blocks.

    Block 0 is reserved (null block) and never handed out.
    """

    def __init__(self, num_blocks: int):
        if num_blocks < 2:
            raise ValueError("need at least one allocatable block beyond null")
        self.num_blocks = num_blocks
        # LIFO keeps recently-freed blocks hot; ids 1..num_blocks-1.
        self._free = list(range(num_blocks - 1, 0, -1))

    @property
    def available(self) -> int:
        return len(self._free)

    def alloc(self, n: int):
        """Allocate ``n`` blocks, or return None (and nothing) if short."""
        if n > len(self._free):
            return None
        taken = [self._free.pop() for _ in range(n)]
        return taken

    def free(self, blocks) -> None:
        for b in blocks:
            if not 1 <= b < self.num_blocks:
                raise ValueError(f"freeing invalid block {b}")
            if b in self._free:
                raise ValueError(f"double free of block {b}")
            self._free.append(b)


class PagedKV:
    """Block-pooled storage for the ``k``/``v`` leaves of a family cache.

    ``templates`` maps leaf name -> per-request dense leaf of shape
    ``(lead, 1, view_len, KV, Dh)`` (the shape ``init_cache(batch=1)``
    produces); all leaves share one block table per request slot.
    """

    def __init__(self, templates, *, block_size: int, max_requests: int,
                 num_blocks: int | None = None):
        shapes = {n: tuple(t.shape) for n, t in templates.items()}
        view_lens = {s[2] for s in shapes.values()}
        if len(view_lens) != 1:
            raise ValueError(f"paged leaves disagree on view length: {shapes}")
        (self.view_len,) = view_lens
        if self.view_len % block_size != 0:
            raise ValueError(
                f"view length {self.view_len} not divisible by "
                f"block size {block_size}")
        self.block_size = block_size
        self.blocks_per_request = self.view_len // block_size
        self.max_requests = max_requests
        if num_blocks is None:
            num_blocks = 1 + max_requests * self.blocks_per_request
        self.pool_mgr = BlockPool(num_blocks)
        self.pools = {
            n: jnp.zeros(
                (t.shape[0], num_blocks, block_size) + tuple(t.shape[3:]),
                t.dtype)
            for n, t in templates.items()
        }
        self._tables = np.zeros((max_requests, self.blocks_per_request),
                                np.int32)
        self._owned: dict[int, list[int]] = {}
        self._tables_dev = None

    # -- allocation --------------------------------------------------------

    @property
    def available_blocks(self) -> int:
        return self.pool_mgr.available

    def admit(self, slot: int) -> bool:
        """Allocate a full block table for request slot ``slot``."""
        if slot in self._owned:
            raise ValueError(f"slot {slot} already admitted")
        blocks = self.pool_mgr.alloc(self.blocks_per_request)
        if blocks is None:
            return False
        self._owned[slot] = blocks
        self._tables[slot] = blocks
        self._tables_dev = None
        return True

    def release(self, slot: int) -> None:
        """Free ``slot``'s blocks (retire or preempt)."""
        self.pool_mgr.free(self._owned.pop(slot))
        self._tables[slot] = 0
        self._tables_dev = None

    def blocks_of(self, slot: int):
        return list(self._owned[slot])

    @property
    def tables(self) -> jnp.ndarray:
        """(max_requests, blocks_per_request) int32 block table, on device."""
        if self._tables_dev is None:
            self._tables_dev = jnp.asarray(self._tables)
        return self._tables_dev

    # -- data movement -----------------------------------------------------

    def write_view(self, slot: int, views) -> None:
        """Scatter a dense per-request view into ``slot``'s blocks.

        ``views`` maps leaf name -> ``(lead, 1, view_len, KV, Dh)`` (the
        batch-1 cache leaf). Used after prefill: the prefilled dense cache
        leaf lands in the freshly allocated blocks.
        """
        blocks = tuple(self._owned[slot])
        nb, bs = self.blocks_per_request, self.block_size
        for name, view in views.items():
            pool = self.pools[name]
            v = jnp.asarray(view)
            assert v.shape[1] == 1 and v.shape[2] == self.view_len, v.shape
            v = v[:, 0]                      # (lead, view_len, ...)
            lead = v.shape[0]
            v = v.reshape(lead, nb, bs, *v.shape[2:])
            self.pools[name] = pool.at[:, blocks].set(v)

    def gather(self):
        """Dense views for every slot: leaf -> (R, lead, 1, view_len, ...).

        Inactive slots read the null block (garbage, discarded).
        """
        return gather_views(self.pools, self.tables, self.block_size)

    def scatter_token(self, views, positions) -> None:
        """Write back the one view slot each request just filled.

        ``views`` maps leaf name -> ``(R, lead, 1, view_len, ...)`` (the
        post-decode dense views); ``positions`` is the ``(R,)`` int32 ring
        slot each request wrote (``old_length % view_len``).
        """
        self.pools = scatter_tokens(self.pools, self.tables, views,
                                    positions, self.block_size)
