"""Production serving tier: continuous batching over a paged KV cache.

Layering (bottom to top):

  * ``paged_kv``   — block pool + free list + per-request block tables;
                     the only code that touches pool storage layout.
  * ``scheduler``  — pure-python continuous-batching policy: arrival
                     queue, token-budget admission, SLO-aware
                     prefill/decode interleave, mid-flight join/retire.
  * ``engine``     — JAX execution: per-family prefill + vmapped decode
                     over fixed request slots, paged KV views, tuned TP
                     decode collectives via ``Communicator``, and
                     per-request latency records for ``decode_summary``.

``launch/serve.py`` is a thin CLI over this package; the fixed-batch
path there remains the validation oracle for everything here.
"""
from repro.serve.paged_kv import BlockPool, PagedKV
from repro.serve.scheduler import Request, Scheduler, load_trace, \
    synthetic_trace
from repro.serve.engine import ServeEngine, ServeResult

__all__ = [
    "BlockPool",
    "PagedKV",
    "Request",
    "Scheduler",
    "load_trace",
    "synthetic_trace",
    "ServeEngine",
    "ServeResult",
]
