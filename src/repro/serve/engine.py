"""Serving engine: vmapped per-request decode over paged KV + tuned TP.

Execution model
---------------
The engine owns ``max_active`` fixed request *slots* (so every step has
static shapes — no recompiles as requests join/retire mid-flight). The
family cache from ``api.init_cache(batch=1, view_len)`` is split into:

  * paged leaves — the top-level attention ``k``/``v`` tensors, stored in
    a :class:`~repro.serve.paged_kv.PagedKV` block pool and materialized
    per step as dense per-request views through the block tables;
  * opaque per-request state — everything else (SSM conv/ssd state,
    enc-dec cross KV, ...), stacked along a leading slot axis;
  * lengths — one engine-owned ``(max_active,)`` vector (per-request
    scalar under vmap), replacing the cache's scalar ``length``.

One jitted step gathers the views, runs ``jax.vmap(api.decode_step)``
with batch-1 per request, scatters each request's newly written KV slot
back into its blocks, and argmaxes the next token. Each vmap instance is
exactly the dense single-request decode — paged serving is therefore
bit-identical to the per-request dense oracle by construction (the
correctness tests assert this across every registry family).

With a mesh + ``Communicator`` the whole step runs under ``shard_map``
and the per-token logits assembly goes through the tuned collective —
the same masked-all_reduce / transposed-all_gather construction as
``launch.tp_decode.build_tp_decode_step``, built from the same request
objects ``Communicator.explain`` renders, so the reported decode plan is
exactly the executed plan. Decode logits at serving batch sizes are
KB-scale messages: the small-message end of the tuning grid.

Timing is injected: with ``cost_model=None`` the run loop uses the wall
clock; a ``cost_model(kind, n) -> seconds`` callable switches every
duration (and the arrival clock) to deterministic simulated time, which
is what the serving benchmark gates on.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.paged_kv import PagedKV, gather_views, scatter_tokens

PAGED_LEAVES = ("k", "v")


@dataclasses.dataclass
class ServeResult:
    """Outcome of a serving run: aggregate latency/throughput + spans."""
    summary: dict
    records: list
    wall_s: float


class ServeEngine:
    def __init__(self, api, params, *, max_active: int = 4,
                 view_len: int = 64, block_size: int = 8,
                 num_blocks: Optional[int] = None,
                 mesh=None, comm=None, collective: str = "all_gather",
                 axis: str = "model",
                 prefill_extra: Optional[Callable] = None):
        if api.prefill is None or api.decode_step is None:
            raise ValueError(f"family {api.cfg.family} cannot serve "
                             "(needs prefill + decode_step)")
        self.api = api
        self.params = params
        self.max_active = max_active
        self.view_len = view_len
        self.block_size = block_size
        # per-request inputs beyond the token prompt (encdec: audio)
        self.prefill_extra = prefill_extra or (lambda req: {})

        tmpl = api.init_cache(1, view_len)
        self._has_length = "length" in tmpl
        paged_tmpl = {n: tmpl[n] for n in PAGED_LEAVES if n in tmpl}
        self.paged_names = tuple(paged_tmpl)
        self.paged = PagedKV(paged_tmpl, block_size=block_size,
                             max_requests=max_active,
                             num_blocks=num_blocks) if paged_tmpl else None
        opaque_tmpl = {n: v for n, v in tmpl.items()
                       if n not in self.paged_names and n != "length"}
        R = max_active
        self.opaque = jax.tree.map(
            lambda a: jnp.zeros((R,) + a.shape, a.dtype), opaque_tmpl)
        self.lengths = jnp.zeros((R,), jnp.int32)
        self.cur_tokens = jnp.zeros((R,), jnp.int32)
        self._free_slots = list(range(R - 1, -1, -1))
        self._active_mask = np.zeros((R,), bool)
        self._slot_req: dict[int, object] = {}

        self._mesh = mesh
        self._comm = comm
        self._collective = collective
        self._axis = axis
        self._tp = mesh.shape[axis] if (mesh is not None and
                                        comm is not None) else 0
        self._prefill = jax.jit(
            lambda params, tokens, **extra:
            self.api.prefill(params, tokens, self.view_len, **extra))
        self._step = self._build_step()

    # -- tuned decode plan -------------------------------------------------

    def decode_requests(self):
        """The decode-step collective requests (for ``explain()``) — same
        builders as the executed step, batch = the slot count."""
        from repro.launch.tp_decode import decode_requests
        cfg = self.api.cfg
        return decode_requests(self.max_active, cfg.d_model, cfg.vocab_size,
                               max(self._tp, 2), axis=self._axis)

    # -- jitted step -------------------------------------------------------

    def _build_step(self):
        api, R = self.api, self.max_active
        T, bs = self.view_len, self.block_size
        paged_names, has_length = self.paged_names, self._has_length
        tp, ax, collective = self._tp, self._axis, self._collective
        comm = self._comm

        def one(params, view, opq, ln, tok):
            cache = {**opq, **view}
            if has_length:
                cache["length"] = ln
            logits, nc = api.decode_step(params, cache, tok[None, None])
            new_len = nc.pop("length", ln + 1)
            paged_out = {n: nc.pop(n) for n in paged_names}
            return logits[0], paged_out, nc, new_len

        def step(params, pools, tables, opaque, lengths, tokens, active):
            views = (gather_views(pools, tables, bs) if paged_names else {})
            logits, new_views, new_opq, new_lens = jax.vmap(
                one, in_axes=(None, 0, 0, 0, 0))(
                params, views, opaque, lengths, tokens)
            if tp:
                from repro.launch.tp_decode import logits_request
                from repro.core.collectives.dispatch import apply_collective
                V = logits.shape[-1]
                assert V % tp == 0, f"vocab {V} not divisible by tp={tp}"
                shard = V // tp
                r = jax.lax.axis_index(ax)
                req = logits_request(collective, R, V, tp, axis=ax,
                                     itemsize=logits.dtype.itemsize,
                                     dtype=str(logits.dtype))
                spec = comm.spec(req)
                if collective == "all_gather":
                    own = jax.lax.dynamic_slice_in_dim(
                        logits, r * shard, shard, axis=-1)
                    logits = apply_collective("all_gather", own.T, ax, tp,
                                              spec).T
                else:
                    cols = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                                    logits.ndim - 1)
                    masked = jnp.where(cols // shard == r, logits,
                                       jnp.zeros_like(logits))
                    logits = apply_collective("all_reduce", masked, ax, tp,
                                              spec)
            pos = lengths % T
            new_pools = (scatter_tokens(pools, tables, new_views, pos, bs)
                         if paged_names else pools)
            new_lengths = jnp.where(active, new_lens, lengths)
            next_tok = jnp.argmax(logits, -1).astype(jnp.int32)
            return logits, next_tok, new_pools, new_opq, new_lengths

        if self._tp:
            from jax.sharding import PartitionSpec as P
            from repro import compat
            step = compat.shard_map(
                step, mesh=self._mesh,
                in_specs=(P(),) * 7, out_specs=(P(),) * 5,
                check_vma=False)
        return jax.jit(step)

    # -- request lifecycle -------------------------------------------------

    def admit(self, req) -> int:
        """Prefill ``req`` into a free slot; returns the slot. The first
        generated token comes from the prefill logits."""
        if not self._free_slots:
            raise RuntimeError("no free request slot")
        assert req.prompt_len <= self.view_len, \
            f"prompt {req.prompt_len} exceeds KV view {self.view_len}"
        slot = self._free_slots[-1]
        if self.paged is not None and not self.paged.admit(slot):
            raise RuntimeError("KV block pool exhausted")
        self._free_slots.pop()
        tokens = jnp.asarray(np.asarray(req.prompt, np.int32))[None]
        logits, cache = self._prefill(self.params, tokens,
                                      **self.prefill_extra(req))
        if self.paged is not None:
            self.paged.write_view(slot, {n: cache[n]
                                         for n in self.paged_names})
        opq = {n: v for n, v in cache.items()
               if n not in self.paged_names and n != "length"}
        self.opaque = jax.tree.map(lambda st, leaf: st.at[slot].set(leaf),
                                   self.opaque, opq)
        self.lengths = self.lengths.at[slot].set(req.prompt_len)
        tok0 = jnp.argmax(logits[0, -1]).astype(jnp.int32)
        self.cur_tokens = self.cur_tokens.at[slot].set(tok0)
        self._active_mask[slot] = True
        self._slot_req[slot] = req
        return slot

    def release(self, slot: int) -> None:
        """Free a slot (retire or preempt): blocks back to the pool."""
        if self.paged is not None:
            self.paged.release(slot)
        self._active_mask[slot] = False
        self._slot_req.pop(slot, None)
        self._free_slots.append(slot)

    def step(self):
        """One decode step for every active slot. Returns {slot: token}."""
        tables = (self.paged.tables if self.paged is not None
                  else jnp.zeros((self.max_active, 1), jnp.int32))
        pools = self.paged.pools if self.paged is not None else {}
        active = jnp.asarray(self._active_mask)
        logits, next_tok, new_pools, new_opq, new_lens = self._step(
            self.params, pools, tables, self.opaque, self.lengths,
            self.cur_tokens, active)
        if self.paged is not None:
            self.paged.pools = new_pools
        self.opaque = new_opq
        self.lengths = new_lens
        self.cur_tokens = jnp.where(active, next_tok, self.cur_tokens)
        toks = np.asarray(next_tok)      # sync point: honest token latency
        return {s: int(toks[s]) for s in range(self.max_active)
                if self._active_mask[s]}

    # -- serving loop ------------------------------------------------------

    def run(self, sched, *, cost_model: Optional[Callable] = None,
            max_steps: int = 100000) -> ServeResult:
        """Drive the scheduler to completion.

        ``cost_model(kind, n) -> seconds`` (kinds: ``"prefill"`` with the
        prompt length, ``"decode"`` with the active count) switches the
        run to deterministic simulated time; otherwise wall clock.
        """
        sim = cost_model is not None
        wall0 = time.perf_counter()
        now = 0.0 if sim else time.perf_counter()

        def idle_until(t):
            nonlocal now
            if sim:
                now = max(now, t)
            else:
                wait = t - time.perf_counter()
                if wait > 0:
                    time.sleep(wait)
                now = time.perf_counter()

        if not sim:
            # express trace arrivals relative to run start
            base = now
            for r in list(sched.pending):
                r.arrival_s += base

        steps = 0
        while not sched.done and steps < max_steps:
            steps += 1
            if not sched.active:
                nxt = sched.next_arrival()
                if nxt is not None and nxt > now:
                    idle_until(nxt)
            for req in sched.admissible(now):
                t0 = now if sim else time.perf_counter()
                slot = self.admit(req)
                if sim:
                    now += cost_model("prefill", req.prompt_len)
                    dur_ms = 1e3 * cost_model("prefill", req.prompt_len)
                else:
                    now = time.perf_counter()
                    dur_ms = 1e3 * (now - t0)
                sched.start(req, now, slot)
                sched.note_prefill(dur_ms)
                # first token is produced by the prefill itself
                tok0 = int(np.asarray(self.cur_tokens)[slot])
                sched.record_token(req, tok0, now)
            if sched.active:
                toks = self.step()
                if sim:
                    now += cost_model("decode", len(toks))
                else:
                    now = time.perf_counter()
                for slot, tok in toks.items():
                    req = self._slot_req.get(slot)
                    if req is not None and len(req.generated) < req.max_new:
                        sched.record_token(req, tok, now)
                sched.note_decode(now)
            for req in sched.retire_done(now):
                self.release(req.slot)

        assert sched.done, f"serving loop hit max_steps={max_steps}"
        summary = sched.latency_summary()
        return ServeResult(
            summary=summary,
            records=[r.record() for r in
                     sorted(sched.finished, key=lambda r: r.rid)],
            wall_s=time.perf_counter() - wall0)
