"""Step builders: train / prefill / decode, with shardings and the tuned
collective path.

``build_step`` is the single entry the launcher, dry-run and tests share:
it returns the jit-able function, example ShapeDtypeStructs and shardings
for every argument — so ``.lower().compile()`` needs no real allocation.

All tuned dispatch (gradient sync, the MoE all-to-all) flows through one
`repro.comms.Communicator` — built here from the CollectiveConfig, or
passed in by a launcher that already probed the fabric.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.comms import Communicator
from repro.configs.base import (
    CollectiveConfig,
    ModelConfig,
    ParallelConfig,
    ShapeConfig,
    validate_collectives,
)
from repro.models.registry import build_model, train_batch_structs
from repro.optim import AdamW, cosine_with_warmup
from repro.parallel import sharding as sh

LONG_CONTEXT_WINDOW = 8192


@dataclasses.dataclass
class ServePlan:
    run: bool
    cache_len: int = 0
    window: int = 0
    reason: str = ""


def serve_plan(cfg: ModelConfig, shape: ShapeConfig) -> ServePlan:
    """Decode policy per DESIGN.md §4."""
    S = shape.seq_len
    if cfg.family == "ssm":
        return ServePlan(run=True, cache_len=0, window=0)
    if cfg.family == "encdec":
        if S > 32_768:
            return ServePlan(run=False, reason=(
                "whisper decoder is architecturally capped; 500k windowed "
                "decoder self-attention exercises nothing real (DESIGN §4)"))
        return ServePlan(run=True, cache_len=S, window=0)
    if S > 32_768:
        # sub-quadratic requirement: sliding window for attention caches
        return ServePlan(run=True, cache_len=LONG_CONTEXT_WINDOW,
                         window=LONG_CONTEXT_WINDOW)
    return ServePlan(run=True, cache_len=S, window=0)


# ---------------------------------------------------------------------------
def build_train_step(
    cfg: ModelConfig,
    shape: ShapeConfig,
    parallel: ParallelConfig,
    coll: CollectiveConfig,
    mesh,
    *,
    lr: float = 3e-4,
    total_steps: int = 1000,
    warmup_steps: int = 100,
    accounting: bool = False,
    communicator: Optional[Communicator] = None,
):
    """Returns (fn, args_structs, in_shardings, out_shardings, donate).

    ``accounting=True`` builds the cost-accounting variant: layer loops
    literally unrolled, un-chunked attention/loss — compile-only, used by the
    dry-run to correct XLA's count-loop-bodies-once cost analysis.

    ``communicator`` is the launch's `Communicator` (one per process,
    built by the launcher — possibly with a live-fabric probe); when None,
    one is resolved from the CollectiveConfig."""
    sh.set_current_mesh(mesh)
    sh.set_seq_sharding(parallel.seq_shard_activations)
    comm = communicator or Communicator.from_config(coll, mesh)
    tuned = comm.is_tuned
    validate_collectives(coll, parallel, tuned=tuned)
    overlap = tuned and coll.overlap_backward
    ep_axis = "model" if (cfg.family == "moe"
                          and sh.model_size(mesh) > 1) else None
    # MoE + tuned sync unify into ONE shard_map program: the model runs
    # inside the manual region, manual over the data axes AND the
    # expert-parallel axis, so the nested expert shard_map is replaced by
    # plain axis collectives (no more mutual exclusion)
    ep_manual = tuned and ep_axis is not None
    api = build_model(
        cfg,
        ep_axis=ep_axis,
        mesh=mesh,
        remat=(parallel.remat != "none"),
        attn_impl="ref" if accounting else
        ("xla" if jax.default_backend() != "tpu" else "auto"),
        # per-layer release points need the unrolled layer stack: a scan
        # traces its body once, so its collectives can't overlap across
        # iterations
        unroll=accounting or overlap,
        loss_chunk=(1 << 30) if accounting else 512,
        a2a_algorithm=comm,
        ep_manual=ep_manual,
    )
    opt = AdamW(lr=lr)

    key = jax.random.PRNGKey(0)
    params_s = jax.eval_shape(api.init, key)
    opt_s = jax.eval_shape(opt.init, params_s)
    batch_s = train_batch_structs(cfg, shape)

    pspecs = sh.param_specs(params_s, cfg, parallel, mesh)
    ospecs = type(opt_s)(step=P(), mu=pspecs, nu=pspecs)
    bspecs = sh.batch_specs(batch_s, mesh, shape)

    dpx = sh.dp_axes(mesh)

    def lr_scale(step):
        return cosine_with_warmup(step, warmup_steps=warmup_steps,
                                  total_steps=total_steps)

    def loss_with_cast(params, batch):
        if parallel.gather_in_compute_dtype:
            params = jax.tree.map(
                lambda p: p.astype(jnp.bfloat16)
                if p.dtype == jnp.float32 else p, params)
        return api.loss(params, batch)

    def grad_fn(params, batch):
        """value_and_grad, optionally microbatched (survey §4.1 CCTP:
        tiling the step so collectives of tile i overlap compute of tile
        i+1 — XLA's latency-hiding scheduler interleaves the per-tile
        gradient collectives with the next tile's backward)."""
        k = max(1, coll.overlap_microbatches)
        if k == 1:
            return jax.value_and_grad(loss_with_cast, has_aux=True)(
                params, batch)
        B = jax.tree.leaves(batch)[0].shape[0]
        assert B % k == 0, f"batch {B} not divisible by {k} microbatches"
        mbs = jax.tree.map(
            lambda a: a.reshape((k, B // k) + a.shape[1:]), batch)

        def body(acc, mb):
            (l, aux), g = jax.value_and_grad(loss_with_cast, has_aux=True)(
                params, mb)
            acc_l, acc_aux, acc_g = acc
            return (acc_l + l / k,
                    jax.tree.map(lambda a, b: a + b / k, acc_aux, aux),
                    jax.tree.map(lambda a, b: a + b / k, acc_g, g)), None

        (l0, aux0), g0 = jax.eval_shape(
            lambda p, b: jax.value_and_grad(loss_with_cast, has_aux=True)(
                p, b), params, jax.tree.map(lambda a: a[0], mbs))
        zeros = lambda t: jax.tree.map(
            lambda x: jnp.zeros(x.shape, x.dtype), t)
        (loss, aux, grads), _ = jax.lax.scan(
            body, (jnp.zeros(l0.shape, l0.dtype), zeros(aux0), zeros(g0)),
            mbs)
        return (loss, aux), grads

    if not tuned:
        def fn(params, opt_state, batch):
            (loss, aux), grads = grad_fn(params, batch)
            new_params, new_opt = opt.update(
                grads, opt_state, params, lr_scale=lr_scale(opt_state.step))
            return new_params, new_opt, {"loss": loss, **aux}
    else:
        # ONE shard_map program end to end: model forward/backward AND the
        # tuned gradient sync run inside the manual region (up to three
        # data tiers "dcn" > "pod" > "data"; plus the expert-parallel
        # "model" axis for MoE, whose all-to-all becomes a plain axis
        # collective — no nested shard_map). Gradient sync through the
        # Communicator: per-leaf flat, psum-topped, or the full N-level
        # hierarchical composition; a fusion-bucket budget coalesces
        # leaves into buckets that overlap-pipeline across tiers; with
        # --overlap-backward, per-layer custom_vjp release points hand
        # each layer's gradients to the release sink DURING backward
        # compute (bucket k's tier-0 reduce-scatter under layer k-1's
        # backward), and `sync_gradients_streamed` finishes the residual
        # — then a local optimizer step on replicated params.
        from repro.models import layers as L

        manual_axes = set(dpx) | ({ep_axis} if ep_manual else set())

        def ep_correct(grads, params):
            """Fix the expert-parallel replica factor. Inside the manual
            region the non-expert compute is replicated over ``ep_axis``
            while each rank's sequence chunk feeds the expert block
            through collectives, so the per-rank backward yields the
            gradient of the SUM of the tp replica losses: expert-shard
            grads carry a clean factor tp, and replicated-param grads
            differ per rank (each sees only its own chunk's expert-path
            contribution). pmean over ``ep_axis`` restores the
            replicated grads exactly (sum over ranks = tp x the true
            gradient); expert shards just divide by tp."""
            tp = compat.axis_size(ep_axis)
            especs = sh.ep_param_specs(params, ep_axis)
            return jax.tree.map(
                lambda g, s: g / tp if s != P()
                else jax.lax.pmean(g, ep_axis), grads, especs)

        def fn(params, opt_state, batch):
            def inner(params, opt_state, batch):
                if overlap:
                    sink = comm.release_sink(coll.bucket_bytes)
                    with L.release_scope(sink):
                        (loss, aux), grads = grad_fn(params, batch)
                    if ep_manual:
                        grads = ep_correct(grads, params)
                    grads = comm.sync_gradients_streamed(grads, sink,
                                                         mean=True)
                else:
                    (loss, aux), grads = grad_fn(params, batch)
                    if ep_manual:
                        grads = ep_correct(grads, params)
                    grads = comm.sync_gradients(grads, mean=True)
                loss = jax.lax.pmean(loss, dpx)
                aux = jax.tree.map(lambda v: jax.lax.pmean(v, dpx), aux)
                new_params, new_opt = opt.update(
                    grads, opt_state, params,
                    lr_scale=lr_scale(opt_state.step))
                return new_params, new_opt, {"loss": loss, **aux}

            if ep_manual:
                # expert weights enter split over the ep axis (matching
                # their storage sharding); everything else replicated
                pin = sh.ep_param_specs(params, ep_axis)
            else:
                pin = jax.tree.map(lambda _: P(), params)
            repo = type(opt_state)(step=P(), mu=pin, nu=pin)
            bspec_local = sh.batch_specs(batch, mesh, shape)
            return compat.shard_map(
                inner, mesh=mesh,
                in_specs=(pin, repo, bspec_local),
                out_specs=(pin, repo, P()),
                axis_names=manual_axes, check_vma=False,
            )(params, opt_state, batch)

    args = (params_s, opt_s, batch_s)
    in_sh = (sh.to_named(pspecs, mesh), sh.to_named(ospecs, mesh),
             sh.to_named(bspecs, mesh))
    out_sh = (sh.to_named(pspecs, mesh), sh.to_named(ospecs, mesh), None)
    return fn, args, in_sh, out_sh, (0, 1)


def build_prefill_step(cfg: ModelConfig, shape: ShapeConfig,
                       parallel: ParallelConfig, coll: CollectiveConfig,
                       mesh, *, accounting: bool = False,
                       communicator: Optional[Communicator] = None):
    """Forward pass producing logits over the prompt (inference-prefill)."""
    sh.set_current_mesh(mesh)
    sh.set_seq_sharding(parallel.seq_shard_activations)
    comm = communicator or Communicator.from_config(coll, mesh)
    ep_axis = "model" if (cfg.family == "moe"
                          and sh.model_size(mesh) > 1) else None
    ai = "ref" if accounting else \
        ("xla" if jax.default_backend() != "tpu" else "auto")
    api = build_model(
        cfg, ep_axis=ep_axis, mesh=mesh, param_dtype=jnp.bfloat16,
        attn_impl=ai, unroll=accounting, a2a_algorithm=comm)

    key = jax.random.PRNGKey(0)
    params_s = jax.eval_shape(api.init, key)
    batch_s = train_batch_structs(cfg, shape)
    batch_s.pop("labels")

    pspecs = sh.param_specs(params_s, cfg, parallel, mesh)
    bspecs = sh.batch_specs(batch_s, mesh, shape)

    from repro.models import layers as L
    from repro.models import transformer as T

    def fn(params, batch):
        if cfg.family == "encdec":
            from repro.models import encdec
            enc = encdec.encode(params, batch["audio"], cfg, attn_impl=ai,
                                unroll=accounting)
            h = encdec.decode_train(params, batch["tokens"], enc, cfg,
                                    attn_impl=ai, unroll=accounting)
            return T.logits_fn(params, h, cfg)[:, -1]
        if cfg.family == "vlm":
            from repro.models import vlm
            x = vlm.assemble_embeds(params, batch, cfg, jnp.bfloat16)
            h = T.forward(params, x, cfg, attn_impl=ai, unroll=accounting)
            return T.logits_fn(params, h, cfg)[:, -1]
        if cfg.family == "moe":
            from repro.models import moe_model
            x = T.embed_tokens(params, batch["tokens"], cfg, jnp.bfloat16)
            h, _ = moe_model.forward(params, x, cfg, ep_axis=ep_axis,
                                     mesh=mesh, attn_impl=ai,
                                     unroll=accounting,
                                     a2a_algorithm=comm)
            return T.logits_fn(params, h, cfg)[:, -1]
        if cfg.family == "ssm":
            from repro.models import ssm
            x = T.embed_tokens(params, batch["tokens"], cfg, jnp.bfloat16)
            h = ssm.forward(params, x, cfg, unroll=accounting)
            return T.logits_fn(params, h, cfg)[:, -1]
        if cfg.family == "hybrid":
            from repro.models import hybrid
            x = T.embed_tokens(params, batch["tokens"], cfg, jnp.bfloat16)
            h = hybrid.forward(params, x, cfg, attn_impl=ai,
                               unroll=accounting)
            return T.logits_fn(params, h, cfg)[:, -1]
        x = T.embed_tokens(params, batch["tokens"], cfg, jnp.bfloat16)
        h = T.forward(params, x, cfg, attn_impl=ai, unroll=accounting)
        return T.logits_fn(params, h, cfg)[:, -1]

    args = (params_s, batch_s)
    in_sh = (sh.to_named(pspecs, mesh), sh.to_named(bspecs, mesh))
    return fn, args, in_sh, None, ()


def build_decode_step(cfg: ModelConfig, shape: ShapeConfig,
                      parallel: ParallelConfig, coll: CollectiveConfig,
                      mesh, *, shard_cache_seq: bool = False,
                      accounting: bool = False):
    """One-token serve step against a seq_len KV cache."""
    sh.set_current_mesh(mesh)
    sh.set_seq_sharding(parallel.seq_shard_activations)
    plan = serve_plan(cfg, shape)
    assert plan.run, plan.reason
    api = build_model(
        cfg, window=plan.window, ep_axis=None, mesh=mesh,
        param_dtype=jnp.bfloat16, unroll=accounting,
        attn_impl="ref" if accounting else
        ("xla" if jax.default_backend() != "tpu" else "auto"))

    key = jax.random.PRNGKey(0)
    params_s = jax.eval_shape(api.init, key)
    B = shape.global_batch
    cache_s = jax.eval_shape(
        functools.partial(api.init_cache, B, max(plan.cache_len, 1)))
    tok_s = jax.ShapeDtypeStruct((B, 1), jnp.int32)

    pspecs = sh.param_specs(params_s, cfg, parallel, mesh)
    cspecs = sh.cache_specs(cache_s, cfg, mesh,
                            shard_cache_seq=shard_cache_seq)
    dpx = sh.dp_axes(mesh)
    tok_spec = P(dpx if B % sh.dp_size(mesh) == 0 else None, None)

    def fn(params, cache, tokens):
        logits, new_cache = api.decode_step(params, cache, tokens)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return nxt, new_cache

    args = (params_s, cache_s, tok_s)
    in_sh = (sh.to_named(pspecs, mesh), sh.to_named(cspecs, mesh),
             NamedSharding(mesh, tok_spec))
    out_sh = (NamedSharding(mesh, tok_spec), sh.to_named(cspecs, mesh))
    return fn, args, in_sh, out_sh, (1,)


def build_step(cfg: ModelConfig, shape: ShapeConfig,
               parallel: Optional[ParallelConfig] = None,
               coll: Optional[CollectiveConfig] = None, mesh=None, **kw):
    parallel = parallel or ParallelConfig()
    coll = coll or CollectiveConfig()
    if shape.kind == "train":
        return build_train_step(cfg, shape, parallel, coll, mesh, **kw)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, shape, parallel, coll, mesh, **kw)
    return build_decode_step(cfg, shape, parallel, coll, mesh, **kw)
