"""Production mesh construction.

Functions, not module-level constants — importing this module never touches
jax device state. The dry-run entrypoint sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; everything else sees the real device count.

Mesh builders return the mesh TOGETHER with its ``Topology`` (the
per-level network description ``repro.core.topology`` tunes against), so
every launcher knows which mesh axis rides which fabric tier.
"""
from __future__ import annotations

from typing import Tuple

import jax

from repro import compat
from repro.core.topology import (
    DEFAULT_LEVEL_PROFILES,
    SYNC_AXES,
    MeshLevel,
    Topology,
    level_names_for,
)


def make_production_mesh(*, multi_pod: bool = False) -> Tuple:
    """16x16 = 256 chips per pod (v5e); 2 pods = 512 chips multi-pod.

    Returns ``(mesh, topology)``: single-pod is one ICI level over "data";
    multi-pod stacks the cross-pod DCN level over "pod" on top of it.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    mesh = compat.make_mesh(shape, axes)
    return mesh, local_topology(mesh)


def make_local_mesh(model_parallel: int = 1, pods: int = 1, dcn: int = 1):
    """Smoke/test mesh over whatever devices exist. ``pods > 1`` splits the
    data axis into ("pod", "data") to exercise the hierarchical gradient
    sync on simulated devices; ``dcn > 1`` stacks the third tier on top
    (("dcn", "pod", "data") — the full host/pod/DCN hierarchy)."""
    n = jax.device_count()
    assert n % (model_parallel * pods * dcn) == 0, \
        f"{n} devices not divisible by {dcn} dcn x {pods} pods x " \
        f"{model_parallel} mp"
    if dcn > 1:
        return compat.make_mesh(
            (dcn, pods, n // (dcn * pods * model_parallel), model_parallel),
            ("dcn", "pod", "data", "model"))
    if pods > 1:
        return compat.make_mesh(
            (pods, n // (pods * model_parallel), model_parallel),
            ("pod", "data", "model"))
    return compat.make_mesh((n // model_parallel, model_parallel),
                            ("data", "model"))


def local_topology(mesh) -> Topology:
    """A Topology matching a local mesh's data axes (default profiles).

    Level names follow the tier count, innermost first: one sync axis is
    the ICI baseline ("intra_pod"); "pod" stacks "cross_pod" on top; a
    "dcn" axis pushes the naming down a tier (data becomes "intra_host",
    pod "intra_pod", dcn "cross_pod") — the same rule as
    ``Topology.from_spec``."""
    axes = [a for a in SYNC_AXES if a in mesh.axis_names]
    names = level_names_for(len(axes))
    return Topology(tuple(
        MeshLevel(name, mesh.shape[axis], DEFAULT_LEVEL_PROFILES[name],
                  axis=axis)
        for name, axis in zip(names, axes)))
