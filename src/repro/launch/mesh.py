"""Production mesh construction.

Functions, not module-level constants — importing this module never touches
jax device state. The dry-run entrypoint sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; everything else sees the real device count.

Mesh builders return the mesh TOGETHER with its ``Topology`` (the
per-level network description ``repro.core.topology`` tunes against), so
every launcher knows which mesh axis rides which fabric tier.
"""
from __future__ import annotations

from typing import Tuple

import jax

from repro import compat
from repro.core.topology import DEFAULT_LEVEL_PROFILES, MeshLevel, Topology


def make_production_mesh(*, multi_pod: bool = False) -> Tuple:
    """16x16 = 256 chips per pod (v5e); 2 pods = 512 chips multi-pod.

    Returns ``(mesh, topology)``: single-pod is one ICI level over "data";
    multi-pod stacks the cross-pod DCN level over "pod" on top of it.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    mesh = compat.make_mesh(shape, axes)
    return mesh, local_topology(mesh)


def make_local_mesh(model_parallel: int = 1, pods: int = 1):
    """Smoke/test mesh over whatever devices exist. ``pods > 1`` splits the
    data axis into ("pod", "data") to exercise the hierarchical gradient
    sync on simulated devices."""
    n = jax.device_count()
    assert n % (model_parallel * pods) == 0, \
        f"{n} devices not divisible by {pods} pods x {model_parallel} mp"
    if pods > 1:
        return compat.make_mesh(
            (pods, n // (pods * model_parallel), model_parallel),
            ("pod", "data", "model"))
    return compat.make_mesh((n // model_parallel, model_parallel),
                            ("data", "model"))


def local_topology(mesh) -> Topology:
    """A Topology matching a local mesh's data axes (default profiles)."""
    levels = [MeshLevel("intra_pod", mesh.shape["data"],
                        DEFAULT_LEVEL_PROFILES["intra_pod"], axis="data")]
    if "pod" in mesh.axis_names:
        levels.append(MeshLevel("cross_pod", mesh.shape["pod"],
                                DEFAULT_LEVEL_PROFILES["cross_pod"],
                                axis="pod"))
    return Topology(tuple(levels))
