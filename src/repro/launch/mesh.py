"""Production mesh construction.

Functions, not module-level constants — importing this module never touches
jax device state. The dry-run entrypoint sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; everything else sees the real device count.
"""
from __future__ import annotations

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod (v5e); 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_local_mesh(model_parallel: int = 1):
    """Smoke/test mesh over whatever devices exist."""
    n = jax.device_count()
    assert n % model_parallel == 0
    return compat.make_mesh((n // model_parallel, model_parallel),
                            ("data", "model"))
