"""Production mesh construction.

Functions, not module-level constants — importing this module never touches
jax device state. The dry-run entrypoint sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; everything else sees the real device count.

Mesh builders return the mesh TOGETHER with its ``Topology`` (the
per-level network description ``repro.core.topology`` tunes against), so
every launcher knows which mesh axis rides which fabric tier.
"""
from __future__ import annotations

from typing import Tuple

import jax

from repro import compat
from repro.core.topology import (
    DEFAULT_LEVEL_PROFILES,
    SYNC_AXES,
    MeshLevel,
    Topology,
    level_names_for,
)


def make_production_mesh(*, multi_pod: bool = False) -> Tuple:
    """16x16 = 256 chips per pod (v5e); 2 pods = 512 chips multi-pod.

    Returns ``(mesh, topology)``: single-pod is one ICI level over "data";
    multi-pod stacks the cross-pod DCN level over "pod" on top of it.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    mesh = compat.make_mesh(shape, axes)
    return mesh, local_topology(mesh)


def local_mesh_spec(model_parallel: int = 1, pods: int = 1, dcn: int = 1
                    ) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """The ``(shape, axes)`` `make_local_mesh` will build over the
    attached devices — shared with the placement sweep, so candidates
    are enumerated for exactly the mesh the launch constructs. Raises
    `ValueError` (not an assert: ``python -O`` must still catch it)
    naming the offending CLI values when the device count doesn't
    tile."""
    n = jax.device_count()
    if n % (model_parallel * pods * dcn) != 0:
        raise ValueError(
            f"{n} attached devices cannot tile --dcn={dcn} x "
            f"--pods={pods} x --model-parallel={model_parallel} "
            f"(= {model_parallel * pods * dcn} ranks); pick factors "
            f"of {n}")
    if dcn > 1:
        return ((dcn, pods, n // (dcn * pods * model_parallel),
                 model_parallel), ("dcn", "pod", "data", "model"))
    if pods > 1:
        return ((pods, n // (pods * model_parallel), model_parallel),
                ("pod", "data", "model"))
    return ((n // model_parallel, model_parallel), ("data", "model"))


def make_local_mesh(model_parallel: int = 1, pods: int = 1, dcn: int = 1,
                    *, mapping=None):
    """Smoke/test mesh over whatever devices exist. ``pods > 1`` splits the
    data axis into ("pod", "data") to exercise the hierarchical gradient
    sync on simulated devices; ``dcn > 1`` stacks the third tier on top
    (("dcn", "pod", "data") — the full host/pod/DCN hierarchy).

    ``mapping`` (a swept `MeshMapping`, e.g. from ``--tune-mapping`` or a
    placement-tuned artifact) builds the mesh in the mapping's tuned
    device order instead of the default; it must target the same axes
    and shape this call would construct."""
    shape, axes = local_mesh_spec(model_parallel, pods, dcn)
    if mapping is not None:
        if tuple(mapping.axes) != axes or tuple(mapping.shape) != shape:
            raise ValueError(
                f"mesh mapping targets axes={mapping.axes} "
                f"shape={mapping.shape} but this launch builds "
                f"axes={axes} shape={shape}")
        return mapping.build_mesh()
    return compat.make_mesh(shape, axes)


def local_topology(mesh) -> Topology:
    """A Topology matching a local mesh's data axes (default profiles).

    Level names follow the tier count, innermost first: one sync axis is
    the ICI baseline ("intra_pod"); "pod" stacks "cross_pod" on top; a
    "dcn" axis pushes the naming down a tier (data becomes "intra_host",
    pod "intra_pod", dcn "cross_pod") — the same rule as
    ``Topology.from_spec``. Sync axes follow the MESH's nesting order
    (innermost first), not the canonical tuple's, so a permuted mesh
    still gets its innermost axis on the fastest tier."""
    axes = [a for a in reversed(tuple(mesh.axis_names))
            if a in SYNC_AXES]
    names = level_names_for(len(axes))
    return Topology(tuple(
        MeshLevel(name, mesh.shape[axis], DEFAULT_LEVEL_PROFILES[name],
                  axis=axis)
        for name, axis in zip(names, axes)))
