"""Roofline-term extraction from compiled artifacts.

collective_bytes is NOT in cost_analysis — we parse the optimized HLO and
sum operand bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op (per-device bytes-on-wire proxy).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

# v5e hardware constants (per brief)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|s32|s16|s8|"
                       r"u64|u32|u16|u8|pred|c64|c128)\[([0-9,]*)\]")

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.groups()
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum of output bytes per collective kind (one device's traffic)."""
    out = {k: 0 for k in _COLL_KINDS}
    out["count"] = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        # match "<var> = <shape(s)> <op>(" — ops may be suffixed -start/-done
        m = re.match(r"%?[\w.\-]+ = (.+?) ([\w\-]+)\(", s)
        if not m:
            continue
        shape_part, opname = m.groups()
        base = opname
        for suffix in ("-start", "-done"):
            if base.endswith(suffix):
                base = base[: -len(suffix)]
        if base in _COLL_KINDS:
            if opname.endswith("-done"):
                continue  # avoid double count of async pairs
            out[base] += _shape_bytes(shape_part)
            out["count"] += 1
    return out


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float = 0.0
    useful_ratio: float = 0.0

    def as_dict(self):
        return dataclasses.asdict(self)


def roofline(cost: dict, coll: Dict[str, int], *, chips: int,
             model_flops_global: float = 0.0) -> Roofline:
    """cost = compiled.cost_analysis() (PER-DEVICE program); coll from
    collective_bytes()."""
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    cbytes = float(sum(v for k, v in coll.items() if k != "count"))
    compute_s = flops / PEAK_FLOPS
    memory_s = byts / HBM_BW
    collective_s = cbytes / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dom = max(terms, key=terms.get)
    mf = model_flops_global / chips
    return Roofline(
        flops_per_device=flops,
        bytes_per_device=byts,
        coll_bytes_per_device=cbytes,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dom,
        model_flops=mf,
        useful_ratio=(mf / flops) if flops else 0.0,
    )


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE); decode D=tokens=B."""
    n = param_count(cfg, active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch   # one token per sequence


def param_count(cfg, *, active_only: bool = False) -> float:
    """Analytic parameter count (embeddings + blocks)."""
    d, L = cfg.d_model, cfg.num_layers
    H, KV, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    n = cfg.vocab_size * d * 2                         # tok + out
    attn = d * (H + 2 * KV) * Dh + H * Dh * d
    if cfg.family in ("dense", "vlm"):
        n += L * (attn + 3 * d * cfg.d_ff)
    elif cfg.family == "moe":
        E = cfg.experts_per_token if active_only else cfg.num_experts
        n += L * (attn + 3 * d * cfg.d_ff * E)
        if cfg.dense_residual:
            n += L * 3 * d * cfg.dense_d_ff
    elif cfg.family == "ssm":
        di, N, Hs = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
        per = d * (2 * di + 2 * N + Hs) + di * d + (cfg.d_conv) * (di + 2 * N)
        n += L * per
    elif cfg.family == "hybrid":
        di, N, Hs = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
        per = d * (2 * di + 2 * N + Hs) + di * d + (cfg.d_conv) * (di + 2 * N)
        n += L * per
        n += attn + 3 * d * cfg.d_ff                   # ONE shared block
    elif cfg.family == "encdec":
        n += cfg.encoder_layers * (attn + 2 * d * cfg.d_ff)
        n += L * (2 * attn + 2 * d * cfg.d_ff)         # self + cross
    return float(n)
