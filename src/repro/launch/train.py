"""Training launcher.

Runs real steps on the available devices (use
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` to simulate a mesh on
CPU). The tuned-collective path is selected with --collective / --decision.

Examples:
    python -m repro.launch.train --arch smollm-135m --reduced --steps 20
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        python -m repro.launch.train --arch smollm-135m --reduced \\
        --steps 20 --collective ring --model-parallel 2
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import save
from repro.configs import ARCHITECTURES, CollectiveConfig, ParallelConfig
from repro.configs.base import ShapeConfig
from repro.data import SyntheticPipeline
from repro.launch.mesh import make_local_mesh
from repro.launch.steps import build_train_step
from repro.models.registry import build_model
from repro.optim import AdamW


def _write_step_trace(args, comm, params, runner, topology, step,
                      wall_ms):
    """One step's telemetry artifacts: replay-measure the gradient-sync
    schedule (real per-task wall times, off the critical path), join it
    against the analytical prediction, write the Perfetto trace + the
    flat summary, and print the drift line the re-tune loop watches."""
    from repro.obs import export as obs_export
    from repro.obs import replay as obs_replay
    from repro.obs import residuals as obs_residuals

    spans = obs_replay.measure_gradient_schedule(
        comm, params, overlap_backward=args.overlap_backward,
        runner=runner)
    names = [lv.name for lv in topology.levels] if topology else None
    obs_export.write_chrome_trace(
        os.path.join(args.trace_dir, f"step{step:03d}.trace.json"),
        spans, level_names=names)
    resid = None
    if topology is not None:
        try:
            resid = obs_residuals.gradient_residual_report(
                comm, params, spans=spans, topology=topology,
                overlap_backward=args.overlap_backward)
        except ValueError as e:
            print(f"trace: residuals skipped ({e})")
    obs_export.write_summary(
        os.path.join(args.trace_dir, f"step{step:03d}.summary.json"),
        counters=comm.metrics, residuals=resid,
        extra={"step": step, "wall_ms": wall_ms,
               "n_tasks": len(spans)})
    if resid is not None:
        print(f"trace: step {step:4d} drift {resid.drift():.3f} "
              f"(measured {resid.measured_tasks()}/{len(resid.tasks)} "
              f"tasks, exposed comm "
              f"{resid.modeled_exposed * 1e6:.0f} us modeled)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m",
                    choices=sorted(ARCHITECTURES))
    ap.add_argument("--reduced", action="store_true",
                    help="2-layer smoke variant instead of the full config")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--collective", default="xla",
                    help="gradient-sync algorithm (xla/ring/rabenseifner/...)")
    ap.add_argument("--tuning-table", default=None,
                    help="path to a tuned DecisionTable artifact (produced "
                         "by TuningSession / examples/autotune_collectives."
                         "py); routes gradient sync through the tuned "
                         "{algorithm, segments} per message size")
    ap.add_argument("--decision", default=None,
                    help="deprecated alias for --tuning-table")
    ap.add_argument("--probe-fabric", action="store_true",
                    help="probe the live fabric before selecting a table "
                         "from a multi-backend artifact (instead of "
                         "first-table-wins)")
    ap.add_argument("--explain", action="store_true",
                    help="print the gradient-sync collective plan "
                         "(algorithm/segments/level — the pipelined "
                         "bucket schedule when bucketing is on) before "
                         "training")
    ap.add_argument("--bucket-mb", type=float, default=None,
                    help="fusion-bucket budget in MiB for the bucketed, "
                         "overlap-pipelined gradient sync (one tuned "
                         "collective per bucket; tier i+1 phases pipeline "
                         "under tier i). Default: the artifact's tuned "
                         "schedule when it carries one; 0 forces the "
                         "sequential per-leaf path")
    ap.add_argument("--overlap-backward", action="store_true",
                    help="backward-overlapped gradient sync: per-layer "
                         "custom_vjp release points issue each layer's "
                         "tier-0 reduce-scatter DURING backward compute, "
                         "on double-buffered permute streams (unrolls the "
                         "layer stack; needs a tuned sync path — "
                         "--tuning-table / --collective / --bucket-mb)")
    ap.add_argument("--topology", default=None,
                    help="network hierarchy: a 'PODSxDATA' spec (e.g. 2x4),"
                         " a 3-tier 'DCNxPODSxDATA' spec (e.g. 2x2x2), or "
                         "a Topology JSON path. Splits the data axis into "
                         "('pod', 'data') — plus 'dcn' on top for three "
                         "tiers; with a schema-3 hierarchical "
                         "--tuning-table, gradient sync runs the per-level "
                         "reduce-scatter / all-reduce / all-gather "
                         "composition across every tier")
    ap.add_argument("--tune-mapping", action="store_true",
                    help="sweep candidate logical->physical device "
                         "placements against the topology's per-level "
                         "network profiles before building the mesh, and "
                         "build it in the winning device order (the "
                         "placement dimension of the collective search "
                         "space; see core/topology/placement.py). An "
                         "artifact stamped with a tuned mapping applies "
                         "it at load without this flag")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--log-every", type=int, default=1)
    ap.add_argument("--trace-dir", default=None,
                    help="write per-step telemetry artifacts here: "
                         "stepNNN.trace.json (Chrome trace-event JSON of "
                         "the gradient-sync schedule, one track per "
                         "(tier, stream) wire — open in Perfetto) and "
                         "stepNNN.summary.json (counters + "
                         "measured-vs-modeled residuals + drift). The "
                         "schedule is re-measured standalone after each "
                         "step (repro.obs.replay), so the numbers are "
                         "real wall times off the critical path; "
                         "residuals need a --topology for the modeled "
                         "side")
    args = ap.parse_args()

    cfg = ARCHITECTURES[args.arch]
    if args.reduced:
        cfg = cfg.reduced()
    shape = ShapeConfig(name="cli", seq_len=args.seq,
                        global_batch=args.batch, kind="train")
    topology = None

    def build_mesh(pods=1, dcn=1):
        """The launch mesh, optionally through the placement sweep:
        --tune-mapping prices every candidate device order on the
        active topology's per-level profiles and builds the winner."""
        mapping = None
        if args.tune_mapping:
            from repro.core.topology import Topology, tune_mesh_mapping
            from repro.launch.mesh import local_mesh_spec
            mesh_shape, mesh_axes = local_mesh_spec(
                model_parallel=args.model_parallel, pods=pods, dcn=dcn)
            sweep_topo = topology or Topology.single_level(
                mesh_shape[mesh_axes.index("data")])
            mapping = tune_mesh_mapping(sweep_topo, axes=mesh_axes,
                                        shape=mesh_shape, attach=False)
            print(f"mesh mapping: {mapping.summary()}")
        return make_local_mesh(model_parallel=args.model_parallel,
                               pods=pods, dcn=dcn, mapping=mapping)

    if args.topology:
        import dataclasses as _dc

        from repro.core.topology import SYNC_AXES, Topology
        if os.path.exists(args.topology):
            topology = Topology.load(args.topology)
        else:
            topology = Topology.from_spec(args.topology)
        # probe-derived topologies carry no mesh axes: assign the sync
        # axes positionally (innermost -> "data", then "pod", then "dcn")
        # so a multi-level topology can never silently degrade to flat
        # sync
        if all(lv.axis is None for lv in topology.levels):
            topology = Topology(tuple(
                _dc.replace(lv, axis=ax)
                for lv, ax in zip(topology.levels, SYNC_AXES)))

        def axis_size(axis):
            lv = next((lv for lv in topology.levels if lv.axis == axis),
                      None)
            return lv.size if lv else 1

        pods, dcn = axis_size("pod"), axis_size("dcn")
        mesh = build_mesh(pods=pods, dcn=dcn)
        data_lv = next((lv for lv in topology.levels if lv.axis == "data"),
                       topology.inner if len(topology.levels) > 1 else None)
        data_spec = data_lv.size if data_lv else None
        if data_spec is not None and mesh.shape["data"] != data_spec:
            raise SystemExit(
                f"--topology names {data_spec} data ranks per group but "
                f"the device count yields {mesh.shape['data']} "
                f"({jax.device_count()} devices / {dcn} dcn / {pods} pods "
                f"/ {args.model_parallel} model-parallel); a table tuned "
                f"at fan-out {data_spec} would silently mis-decide")
        model_lv = next((lv for lv in topology.levels
                         if lv.axis == "model"), None)
        if model_lv is not None and model_lv.size != args.model_parallel:
            raise SystemExit(
                f"--topology names {model_lv.size} model-parallel ranks "
                f"({model_lv.name}) but --model-parallel is "
                f"{args.model_parallel}")
        desc = " > ".join(f"{lv.name}({lv.size})"
                          for lv in reversed(topology.levels))
        print(f"topology: {desc}")
    else:
        mesh = build_mesh()
    parallel = ParallelConfig()
    table_path = args.tuning_table or args.decision
    # the launch's single Communicator: probe -> select -> decide -> dispatch
    from repro.comms import Communicator
    bucket_bytes = None if args.bucket_mb is None \
        else int(args.bucket_mb * (1 << 20))
    comm = Communicator.create(
        mesh, topology=topology, artifact=table_path,
        probe=args.probe_fabric, algorithm=args.collective,
        bucket_bytes=bucket_bytes)
    # an artifact stamped with a tuned mapping rebuilds the mesh at
    # load — everything downstream must shard over THAT mesh
    mesh = comm.mesh
    if table_path:
        print(f"tuning table: {table_path} ({comm.describe()})")
    if comm.mapping is not None and not args.tune_mapping:
        print(f"mesh mapping (from artifact): {comm.mapping.summary()}")
    if comm.bucket_bytes:
        print(f"gradient sync: bucketed overlap pipeline "
              f"(bucket_bytes={comm.bucket_bytes})")
    elif args.probe_fabric:
        print(f"probed fabric: {comm.probed}")
    if args.probe_fabric and comm.probed_topology is not None:
        # per-level probes synthesized a full Topology from the live mesh
        for lv in comm.probed_topology.levels:
            print(f"probed level {lv.name} (axis={lv.axis}, "
                  f"fan-out {lv.size}): launch={lv.profile.launch:.2e}s "
                  f"byte_time={lv.profile.byte_time:.2e}s/B")
    coll = CollectiveConfig(algorithm=args.collective, decision=table_path,
                            bucket_bytes=comm.bucket_bytes,
                            overlap_backward=args.overlap_backward)
    from repro.configs.base import CollectiveConfigError, \
        validate_collectives
    try:
        validate_collectives(coll, parallel, tuned=comm.is_tuned)
    except CollectiveConfigError as e:
        raise SystemExit(f"invalid flags: {e}")
    if args.overlap_backward:
        print("gradient sync: backward-overlapped release streams")

    fn, _, in_sh, out_sh, donate = build_train_step(
        cfg, shape, parallel, coll, mesh, lr=args.lr,
        total_steps=args.steps, communicator=comm)
    step_fn = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                      donate_argnums=donate)

    api = build_model(cfg, attn_impl="xla"
                      if jax.default_backend() != "tpu" else "auto")
    params = jax.device_put(api.init(jax.random.PRNGKey(0)), in_sh[0])
    opt_state = jax.device_put(AdamW(lr=args.lr).init(params), in_sh[1])
    pipe = SyntheticPipeline(cfg, shape, seed=0)

    coll_desc = f"table:{table_path}" if table_path else args.collective
    print(f"arch={cfg.name} devices={jax.device_count()} "
          f"mesh={dict(mesh.shape)} collective={coll_desc}")
    if args.explain:
        if args.overlap_backward:
            print("gradient-sync plan (backward-overlapped streams):")
            print(comm.explain_gradients(
                params, overlap_backward=True).render())
        else:
            print("gradient-sync plan (per leaf):")
            print(comm.explain_gradients(params).render())
    runner = None
    if args.trace_dir:
        from repro.obs import replay as obs_replay
        os.makedirs(args.trace_dir, exist_ok=True)
        # one runner for the whole run: the per-task programs compile
        # once and every step's replay reuses them
        runner = obs_replay.ScheduleRunner(mesh)
        trace_topo = topology or comm.probed_topology
        if trace_topo is None:
            print("trace: no --topology attached, writing traces "
                  "without modeled residuals")
    t_start = time.time()
    for i in range(args.steps):
        batch = jax.device_put(
            {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()},
            in_sh[2])
        t0 = time.time()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        if i % args.log_every == 0:
            print(f"step {i:4d} loss {loss:.4f} "
                  f"({(time.time() - t0) * 1e3:.0f} ms)", flush=True)
        if runner is not None:
            _write_step_trace(args, comm, params, runner, trace_topo, i,
                              wall_ms=(time.time() - t0) * 1e3)
    print(f"done: {args.steps} steps in {time.time() - t_start:.1f}s")

    if args.ckpt:
        save(args.ckpt, {"params": params, "opt": opt_state},
             step=args.steps, extra={"arch": cfg.name})
        print("checkpoint ->", args.ckpt)


if __name__ == "__main__":
    main()
