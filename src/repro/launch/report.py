"""Render EXPERIMENTS.md tables from dry-run JSON artifacts."""
from __future__ import annotations

import glob
import json
import os
from collections import defaultdict


VARIANT_MARKERS = ("seqshard", "bf16gather", "a2a-", "noseqshard", "_chunk")


def load(out_dir="experiments/dryrun", include_variants=False):
    recs = []
    for f in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        if not include_variants and any(m in os.path.basename(f)
                                        for m in VARIANT_MARKERS):
            continue
        rec = json.load(open(f))
        rec["_file"] = os.path.basename(f)
        recs.append(rec)
    return recs


def fmt_bytes(b):
    return f"{b / 1e9:.2f}"


def dryrun_table(recs, mesh="16x16", collective="xla"):
    rows = ["| arch | shape | status | peak GB/dev | fits 16GB | HLO GFLOPs/dev"
            " | HLO GB/dev | coll GB/dev | compile s |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["mesh"] != mesh or r["collective"] != collective:
            continue
        if "seqshard" in json.dumps(r.get("variant", "")):
            continue
        if r["status"] == "skip":
            rows.append(f"| {r['arch']} | {r['shape']} | SKIP | - | - | - |"
                        f" - | - | - |")
            continue
        roof = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | ok "
            f"| {fmt_bytes(r['memory']['peak_bytes_per_device'])} "
            f"| {'Y' if r['fits_16gb_hbm'] else 'N'} "
            f"| {roof['flops_per_device'] / 1e9:.1f} "
            f"| {fmt_bytes(roof['bytes_per_device'])} "
            f"| {fmt_bytes(roof['coll_bytes_per_device'])} "
            f"| {r.get('compile_s', 0)} |")
    return "\n".join(rows)


def roofline_table(recs, mesh="16x16", collective="xla"):
    rows = ["| arch | shape | compute ms | memory ms | collective ms |"
            " dominant | useful ratio | bottleneck lever |",
            "|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["mesh"] != mesh or r["collective"] != collective:
            continue
        if r["status"] == "skip":
            rows.append(f"| {r['arch']} | {r['shape']} | - | - | - | SKIP |"
                        f" - | {r.get('reason', '')[:60]} |")
            continue
        roof = r["roofline"]
        lever = {
            "compute": "more chips / lower precision",
            "memory": ("shard KV cache seq over model axis"
                       if r["shape"].startswith(("decode", "long"))
                       else "activation sharding / remat policy"),
            "collective": ("tuned ring/segmented schedule or 2D sharding"
                           if r["shape"] == "train_4k"
                           else "avoid replicated-cache attention psum"),
        }[roof["dominant"]]
        rows.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {roof['compute_s'] * 1e3:.2f} | {roof['memory_s'] * 1e3:.2f} "
            f"| {roof['collective_s'] * 1e3:.2f} | **{roof['dominant']}** "
            f"| {roof['useful_ratio']:.3f} | {lever} |")
    return "\n".join(rows)


if __name__ == "__main__":
    recs = load()
    print("## single-pod 16x16\n")
    print(dryrun_table(recs))
    print("\n## roofline\n")
    print(roofline_table(recs))
    print("\n## multi-pod 2x16x16\n")
    print(dryrun_table(recs, mesh="2x16x16"))
