"""Tensor-parallel decode through the tuned `Communicator`.

The decode hot loop's collectives are the per-token all-gather of
vocab-parallel logits and the all-reduce of partial logits — this module
routes BOTH through a `repro.comms.Communicator`, so the serving launcher
consumes the artifact instead of only printing the plan. The requests the
step executes and the requests `Communicator.explain` renders are built by
the SAME functions below, so the reported plan is exactly the executed
plan.

Numerics are exact by construction, so tuned decode is bit-identical to
the untuned path (asserted in tests/test_decode_consistency.py):

  * all_gather mode: each rank keeps its contiguous V/p logits columns
    (identical floating-point values to the same columns of the full
    logits) and the tuned all-gather reassembles them in rank order;
  * all_reduce mode: each rank zeroes every column it does not own and
    the tuned sum combines disjoint supports — adding exact zeros never
    perturbs the surviving addend.

On JAX 0.4.x the model compute inside shard_map is replicated (the compat
layer's documented fallback); the collectives still execute the tuned wire
schedule, which is what the decision artifact tunes.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.comms import CollectiveRequest, Communicator, PlanReport
from repro.core.collectives.dispatch import apply_collective

TP_COLLECTIVES = ("all_gather", "all_reduce")


def logits_request(collective: str, batch: int, vocab: int, p: int,
                   *, axis: str = "model", itemsize: int = 2,
                   dtype: str = "bfloat16") -> CollectiveRequest:
    """The decode loop's logits-assembly request: the V/p shard for
    all_gather, the full (Megatron-padded) buffer for all_reduce — the
    exact lookup ``build_tp_decode_step`` performs per token."""
    from repro.models.layers import pad_vocab
    nbytes = batch * pad_vocab(vocab) * itemsize
    if collective == "all_gather":
        nbytes //= p
    return CollectiveRequest(collective, nbytes, axis=axis, axis_size=p,
                             dtype=dtype)


def decode_requests(batch: int, d_model: int, vocab: int, p: int,
                    *, axis: str = "model", itemsize: int = 2
                    ) -> List[CollectiveRequest]:
    """All decode-time collective requests of a TP model: the per-layer
    residual all-reduce and the vocab-parallel logits all-gather."""
    return [
        CollectiveRequest("all_reduce", batch * d_model * itemsize,
                          axis=axis, axis_size=p, dtype="bfloat16"),
        logits_request("all_gather", batch, vocab, p, axis=axis,
                       itemsize=itemsize),
    ]


def tp_decode_plan(comm: Communicator, batch: int, d_model: int,
                   vocab: int, p: int, itemsize: int = 2) -> PlanReport:
    """The decode-time collective plan the serving launcher reports before
    entering the loop — rendered by `Communicator.explain` over the same
    requests the step functions build."""
    return comm.explain(decode_requests(batch, d_model, vocab, p,
                                        itemsize=itemsize))


def executed_spec(comm: Communicator, collective: str, batch: int,
                  vocab: int, p: int, itemsize: int = 2):
    """(nbytes, spec) of the logits collective ``build_tp_decode_step``
    will actually run — same request builder as the step function, so the
    launcher reports exactly what executes."""
    req = logits_request(collective, batch, vocab, p, itemsize=itemsize)
    return req.nbytes, comm.spec(req)


def build_tp_decode_step(api, mesh, comm: Communicator, *,
                         collective: str = "all_gather",
                         axis: str = "model"):
    """A jit-able ``step(params, cache, tokens) -> (logits, cache)`` whose
    per-token logits assembly runs the tuned collective over ``axis``."""
    assert collective in TP_COLLECTIVES, collective
    p = mesh.shape[axis]

    def inner(params, cache, tok):
        logits, new_cache = api.decode_step(params, cache, tok)
        V = logits.shape[-1]
        assert V % p == 0, f"vocab {V} not divisible by tp={p}"
        shard = V // p
        r = jax.lax.axis_index(axis)
        # the wire message: the V/p shard for all_gather, the full masked
        # logits buffer for all_reduce — the same request explain() renders
        req = logits_request(collective, logits.shape[0], V, p, axis=axis,
                             itemsize=logits.dtype.itemsize,
                             dtype=str(logits.dtype))
        spec = comm.spec(req)
        if collective == "all_gather":
            # vocab-parallel: own columns, transposed so the gather's
            # leading-axis concatenation lands in rank order
            own = jax.lax.dynamic_slice_in_dim(logits, r * shard, shard,
                                               axis=-1)
            gathered = apply_collective("all_gather", own.T, axis, p, spec)
            logits = gathered.T
        else:
            # partial-sum form: zero the columns other ranks own; the
            # tuned all-reduce of disjoint supports is an exact reassembly
            cols = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                            logits.ndim - 1)
            masked = jnp.where(cols // shard == r, logits,
                               jnp.zeros_like(logits))
            logits = apply_collective("all_reduce", masked, axis, p, spec)
        return logits, new_cache

    shard_mapped = compat.shard_map(
        inner, mesh=mesh,
        in_specs=(P(), P(), P()),
        out_specs=(P(), P()),
        check_vma=False)
    return jax.jit(shard_mapped)
