"""Tensor-parallel decode through tuned collectives.

The decode hot loop's collectives are the per-token all-gather of
vocab-parallel logits and the all-reduce of partial logits — this module
routes BOTH through a ``DecisionSource`` (a tuned ``TableDecision`` or a
``HierarchicalDecision``), so the serving launcher consumes the artifact
instead of only printing the plan.

Numerics are exact by construction, so tuned decode is bit-identical to
the untuned path (asserted in tests/test_decode_consistency.py):

  * all_gather mode: each rank keeps its contiguous V/p logits columns
    (identical floating-point values to the same columns of the full
    logits) and the tuned all-gather reassembles them in rank order;
  * all_reduce mode: each rank zeroes every column it does not own and
    the tuned sum combines disjoint supports — adding exact zeros never
    perturbs the surviving addend.

On JAX 0.4.x the model compute inside shard_map is replicated (the compat
layer's documented fallback); the collectives still execute the tuned wire
schedule, which is what the decision artifact tunes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.collectives.api import DecisionSource, apply_collective

TP_COLLECTIVES = ("all_gather", "all_reduce")


def build_tp_decode_step(api, mesh, decision: DecisionSource, *,
                         collective: str = "all_gather", axis: str = "model"):
    """A jit-able ``step(params, cache, tokens) -> (logits, cache)`` whose
    per-token logits assembly runs the tuned collective over ``axis``."""
    assert collective in TP_COLLECTIVES, collective
    p = mesh.shape[axis]

    def inner(params, cache, tok):
        logits, new_cache = api.decode_step(params, cache, tok)
        V = logits.shape[-1]
        assert V % p == 0, f"vocab {V} not divisible by tp={p}"
        shard = V // p
        r = jax.lax.axis_index(axis)
        # the wire message: the V/p shard for all_gather, the full masked
        # logits buffer for all_reduce
        nbytes = logits.size * logits.dtype.itemsize
        if collective == "all_gather":
            nbytes //= p
        spec = decision.spec_for(collective, nbytes, p)
        if collective == "all_gather":
            # vocab-parallel: own columns, transposed so the gather's
            # leading-axis concatenation lands in rank order
            own = jax.lax.dynamic_slice_in_dim(logits, r * shard, shard,
                                               axis=-1)
            gathered = apply_collective("all_gather", own.T, axis, p, spec)
            logits = gathered.T
        else:
            # partial-sum form: zero the columns other ranks own; the
            # tuned all-reduce of disjoint supports is an exact reassembly
            cols = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                            logits.ndim - 1)
            masked = jnp.where(cols // shard == r, logits,
                               jnp.zeros_like(logits))
            logits = apply_collective("all_reduce", masked, axis, p, spec)
        return logits, new_cache

    shard_mapped = compat.shard_map(
        inner, mesh=mesh,
        in_specs=(P(), P(), P()),
        out_specs=(P(), P()),
        check_vma=False)
    return jax.jit(shard_mapped)


def tp_decode_plan(decision: DecisionSource, batch: int, d_model: int,
                   vocab: int, p: int, itemsize: int = 2):
    """The (op, nbytes) -> spec plan for a TP model's decode-time messages
    (per-layer residual all-reduce, vocab-parallel logits all-gather) —
    what the serving launcher reports before entering the loop."""
    from repro.models.layers import pad_vocab
    rows = []
    for op, nbytes in (("all_reduce", batch * d_model * itemsize),
                       ("all_gather",
                        batch * pad_vocab(vocab) * itemsize // p)):
        spec = decision.spec_for(op, nbytes, p)
        rows.append((op, nbytes, spec))
    return rows


def executed_spec(decision: DecisionSource, collective: str, batch: int,
                  vocab: int, p: int, itemsize: int = 2):
    """(nbytes, spec) of the logits collective ``build_tp_decode_step``
    will actually run — same lookup as the step function (including the
    Megatron-style vocab padding the logits head applies), so the launcher
    reports exactly what executes."""
    from repro.models.layers import pad_vocab
    nbytes = batch * pad_vocab(vocab) * itemsize
    if collective == "all_gather":
        nbytes //= p
    return nbytes, decision.spec_for(collective, nbytes, p)
