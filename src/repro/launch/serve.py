"""Serving launcher: fixed-batch oracle + continuous batching over paged KV.

Two modes share one model API and one tuned-collective path:

  * default (oracle) — one fixed batch prefilled in a single batched pass
    (``api.prefill``) and greedily decoded to completion. This is the
    validation oracle the continuous path is tested against.
  * ``--continuous`` — the ``repro.serve`` subsystem: a request trace
    (``--request-trace`` JSONL or synthetic Poisson arrivals), paged KV
    blocks, token-budget + SLO admission, per-step join/retire.

With ``--tensor-parallel N --tuning-table ART`` either mode's per-token
logits assembly goes through the `Communicator`'s {algorithm, segments}
choice — bit-identical to the untuned loop, but executing the tuned wire
schedule. Decode messages are KB-scale, so they resolve through the
small-message end of the tuning grid; the printed decode plan is
`Communicator.explain` over the same requests the step executes.
``--probe-fabric`` probes the live fabric first so a multi-backend
artifact resolves to the matching profile's table.

Examples:
    python -m repro.launch.serve --arch smollm-135m --reduced \\
        --prompt-len 32 --gen 32 --batch 4
    python -m repro.launch.serve --arch smollm-135m --reduced \\
        --continuous --num-requests 16 --poisson-rate 50 --slo-ms 200
    XLA_FLAGS=--xla_force_host_platform_device_count=2 \\
        python -m repro.launch.serve --arch smollm-135m --reduced \\
        --tensor-parallel 2 --tuning-table tuned_decision.json
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHITECTURES
from repro.models.registry import build_model


def _prefill_extra_fn(cfg):
    """Per-request inputs beyond the token prompt (encdec: audio)."""
    if cfg.family != "encdec":
        return None

    def mk(req):
        rng = np.random.default_rng(1000 + req.rid)
        return {"audio": jnp.asarray(
            rng.normal(size=(1, cfg.encoder_seq, cfg.d_model)),
            jnp.bfloat16)}
    return mk


def _serve_continuous(args, cfg, api, params, comm, mesh):
    from repro.obs import export as obs_export
    from repro.serve import ServeEngine, Scheduler, load_trace, \
        synthetic_trace

    if args.request_trace:
        trace = load_trace(args.request_trace, vocab=cfg.vocab_size)
    else:
        trace = synthetic_trace(
            args.num_requests, rate_rps=args.poisson_rate,
            vocab=cfg.vocab_size,
            prompt_lens=(max(args.prompt_len // 4, 1),
                         max(args.prompt_len // 2, 1), args.prompt_len),
            max_new=args.gen, seed=0)

    bs = args.block_size
    longest = max(r.prompt_len + r.max_new for r in trace)
    view_len = -(-longest // bs) * bs
    engine = ServeEngine(api, params, max_active=args.max_active,
                         view_len=view_len, block_size=bs,
                         mesh=mesh, comm=comm,
                         collective=args.tp_collective,
                         prefill_extra=_prefill_extra_fn(cfg))
    sched = Scheduler(trace, max_active=args.max_active,
                      token_budget=args.max_active * view_len,
                      slo_ms=args.slo_ms)
    print(f"continuous serving: arch={cfg.name} requests={len(trace)} "
          f"max_active={args.max_active} block={bs} view={view_len} "
          f"slo_ms={args.slo_ms}")
    res = engine.run(sched)
    s = res.summary
    print(f"served {s['requests']} requests, {s['new_tokens']} tokens "
          f"in {res.wall_s:.2f}s ({s['tok_per_s']:.1f} tok/s)")
    print(f"per-token decode latency: p50 {s['token_ms_p50']:.2f} ms  "
          f"p90 {s['token_ms_p90']:.2f} ms  p99 {s['token_ms_p99']:.2f} ms")
    if args.slo_ms:
        ok = s["token_ms_p99"] <= args.slo_ms
        print(f"SLO p99 <= {args.slo_ms:.0f} ms: "
              f"{'met' if ok else 'MISSED'}")

    if args.trace_dir:
        import os
        os.makedirs(args.trace_dir, exist_ok=True)
        obs_export.write_summary(
            os.path.join(args.trace_dir, "decode_summary.json"),
            counters=comm.metrics if comm is not None else None,
            extra={"arch": cfg.name, "mode": "continuous",
                   "tensor_parallel": args.tensor_parallel,
                   "max_active": args.max_active, "block_size": bs,
                   "view_len": view_len, "slo_ms": args.slo_ms,
                   "wall_s": res.wall_s, **s, "requests": res.records})
        print(f"decode summary -> {args.trace_dir}/decode_summary.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m",
                    choices=sorted(ARCHITECTURES))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--window", type=int, default=0)
    ap.add_argument("--continuous", action="store_true",
                    help="serve a request trace with continuous batching "
                         "over paged KV (the repro.serve subsystem) instead "
                         "of one fixed batch")
    ap.add_argument("--request-trace", default=None,
                    help="JSONL request trace ({arrival_s, prompt_len|"
                         "prompt, max_new} per line); default: synthetic "
                         "Poisson arrivals")
    ap.add_argument("--num-requests", type=int, default=16,
                    help="synthetic trace length (--continuous)")
    ap.add_argument("--poisson-rate", type=float, default=50.0,
                    help="synthetic arrival rate, requests/s (--continuous)")
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="per-token latency SLO; admission defers prefills "
                         "that would bust it (--continuous)")
    ap.add_argument("--max-active", type=int, default=4,
                    help="request slots decoded per step (--continuous)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="KV block size in tokens (--continuous)")
    ap.add_argument("--tuning-table", default=None,
                    help="tuned decision artifact (schema 2 or 3); prints "
                         "the tuned collective plan and, with "
                         "--tensor-parallel, drives the decode loop's "
                         "logits collective through it")
    ap.add_argument("--tensor-parallel", type=int, default=0,
                    help=">=2: run the tuned TP decode path over a 'model' "
                         "mesh axis of this size (requires --tuning-table "
                         "and that many devices)")
    ap.add_argument("--tp-collective", default="all_gather",
                    choices=("all_gather", "all_reduce"),
                    help="which tuned collective assembles the TP logits")
    ap.add_argument("--probe-fabric", action="store_true",
                    help="probe the live fabric before selecting a table "
                         "from a multi-backend artifact (instead of "
                         "first-table-wins)")
    ap.add_argument("--trace-dir", default=None,
                    help="write decode_summary.json here (per-token "
                         "latency percentiles + throughput + config; "
                         "with --continuous also per-request spans)")
    args = ap.parse_args()

    cfg = ARCHITECTURES[args.arch]
    if args.reduced:
        cfg = cfg.reduced()

    from repro.comms import Communicator
    comm = None
    if args.tuning_table:
        from repro.launch.tp_decode import tp_decode_plan
        # the launch's single Communicator: probe -> select -> decide ->
        # dispatch (serving only dispatches with --tensor-parallel, but
        # the plan below is resolved through the same object)
        comm = Communicator.create(artifact=args.tuning_table,
                                   probe=args.probe_fabric)
        print(f"tuning table: {args.tuning_table} ({comm.describe()})")
        # decode-time collectives: per-token TP all-reduce of the residual
        # (B, d) and all-gather of vocab-parallel logits (B, V/p)
        p = args.tensor_parallel or max(jax.device_count(), 2)
        batch = args.max_active if args.continuous else args.batch
        print(f"  decode plan p={p}")
        print(tp_decode_plan(comm, batch, cfg.d_model,
                             cfg.vocab_size, p).render(indent="    "))
    api = build_model(cfg, window=args.window,
                      attn_impl="xla" if jax.default_backend() != "tpu"
                      else "auto")
    params = api.init(jax.random.PRNGKey(0))
    B = args.batch
    cache_len = args.prompt_len + args.gen

    mesh = None
    if args.tensor_parallel >= 2:
        if comm is None:
            raise SystemExit("--tensor-parallel needs --tuning-table")
        from repro import compat
        from repro.launch.tp_decode import executed_spec
        tp = args.tensor_parallel
        if jax.device_count() < tp:
            raise SystemExit(f"{tp}-way tensor parallelism needs {tp} "
                             f"devices, have {jax.device_count()} (set "
                             "XLA_FLAGS=--xla_force_host_platform_device_"
                             f"count={tp})")
        mesh = compat.make_mesh((tp,), ("model",))
        batch = args.max_active if args.continuous else args.batch
        nbytes, spec = executed_spec(comm, args.tp_collective,
                                     batch, cfg.vocab_size, tp)
        print(f"tensor-parallel decode: p={tp} via tuned "
              f"{args.tp_collective} ({nbytes} B -> {spec.algorithm} "
              f"segments={spec.segments})")

    if args.continuous:
        _serve_continuous(args, cfg, api, params, comm, mesh)
        return

    # ---- fixed-batch validation oracle ----------------------------------
    if mesh is not None:
        from repro.launch.tp_decode import build_tp_decode_step
        step = build_tp_decode_step(api, mesh, comm,
                                    collective=args.tp_collective)
    else:
        step = jax.jit(api.decode_step)

    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                      (B, args.prompt_len)), jnp.int32)

    # one real batched prefill pass (the same path the scheduler uses)
    extra = {}
    if cfg.family == "encdec":
        extra["audio"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq, cfg.d_model)), jnp.bfloat16)
    t0 = time.time()
    logits, cache = api.prefill(params, prompt, cache_len, **extra)
    logits = logits[:, -1]
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    out = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    # per-token latency: each token is synced before the next issues, so
    # the percentiles are honest tail latencies (the number a serving
    # SLO watches), not async dispatch times
    tok_ms = []
    t0 = time.time()
    for _ in range(args.gen):
        out.append(tok)
        tt0 = time.perf_counter()
        logits, cache = step(params, cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        jax.block_until_ready(tok)
        tok_ms.append((time.perf_counter() - tt0) * 1e3)
    t_gen = time.time() - t0

    gen = jnp.concatenate(out, axis=1)
    p50, p90, p99 = np.percentile(tok_ms, [50, 90, 99])
    print(f"arch={cfg.name} batch={B} prompt={args.prompt_len} "
          f"gen={args.gen}")
    print(f"prefill: {t_prefill:.2f}s  decode: {t_gen:.2f}s "
          f"({B * args.gen / t_gen:.1f} tok/s)")
    print(f"per-token decode latency: p50 {p50:.2f} ms  "
          f"p90 {p90:.2f} ms  p99 {p99:.2f} ms")
    print("sample tokens:", np.asarray(gen[0, :16]).tolist())

    if args.trace_dir:
        import os

        from repro.obs import export as obs_export
        os.makedirs(args.trace_dir, exist_ok=True)
        obs_export.write_summary(
            os.path.join(args.trace_dir, "decode_summary.json"),
            counters=comm.metrics if comm is not None else None,
            extra={"arch": cfg.name, "batch": B,
                   "prompt_len": args.prompt_len, "gen": args.gen,
                   "tensor_parallel": args.tensor_parallel,
                   "prefill_s": t_prefill, "decode_s": t_gen,
                   "tok_per_s": B * args.gen / t_gen,
                   "token_ms_p50": float(p50),
                   "token_ms_p90": float(p90),
                   "token_ms_p99": float(p99)})
        print(f"decode summary -> {args.trace_dir}/decode_summary.json")


if __name__ == "__main__":
    main()
