"""Serving launcher: batched greedy decode against a KV cache.

Example:
    python -m repro.launch.serve --arch smollm-135m --reduced \\
        --prompt-len 32 --gen 32 --batch 4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHITECTURES
from repro.models.registry import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m",
                    choices=sorted(ARCHITECTURES))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--window", type=int, default=0)
    ap.add_argument("--tuning-table", default=None,
                    help="tuned DecisionTable artifact; prints the tuned "
                         "collective plan for this model's decode-time "
                         "message sizes (tensor-parallel serving applies it "
                         "via CollectiveConfig(decision=...))")
    args = ap.parse_args()

    cfg = ARCHITECTURES[args.arch]
    if args.reduced:
        cfg = cfg.reduced()

    if args.tuning_table:
        from repro.core.collectives.api import TableDecision
        from repro.core.tuning.decision import DecisionTable
        table = DecisionTable.load(args.tuning_table)
        decision = TableDecision(table.as_fn())
        p = max(jax.device_count(), 2)
        if table.meta:
            print(f"tuning table: {args.tuning_table} "
                  f"(tuner={table.meta.tuner}, "
                  f"backend={table.meta.backend})")
        # decode-time collectives: per-token TP all-reduce of the residual
        # (B, d) and all-gather of vocab-parallel logits (B, V/p)
        for op, nbytes in (("all_reduce", args.batch * cfg.d_model * 2),
                           ("all_gather",
                            args.batch * cfg.vocab_size * 2 // p)):
            spec = decision.spec_for(op, nbytes, p)
            print(f"  decode plan p={p} {op:12s} {nbytes:>9d} B -> "
                  f"{spec.algorithm} segments={spec.segments}")
    api = build_model(cfg, window=args.window,
                      attn_impl="xla" if jax.default_backend() != "tpu"
                      else "auto")
    params = api.init(jax.random.PRNGKey(0))
    B = args.batch
    cache_len = args.prompt_len + args.gen

    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                      (B, args.prompt_len)), jnp.int32)

    step = jax.jit(api.decode_step)
    cache = api.init_cache(B, cache_len)

    # prefill by stepping the prompt (uniform across families)
    t0 = time.time()
    tok = prompt[:, :1]
    for i in range(args.prompt_len):
        logits, cache = step(params, cache, prompt[:, i:i + 1])
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    out = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    t0 = time.time()
    for _ in range(args.gen):
        out.append(tok)
        logits, cache = step(params, cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    jax.block_until_ready(tok)
    t_gen = time.time() - t0

    gen = jnp.concatenate(out, axis=1)
    print(f"arch={cfg.name} batch={B} prompt={args.prompt_len} "
          f"gen={args.gen}")
    print(f"prefill: {t_prefill:.2f}s  decode: {t_gen:.2f}s "
          f"({B * args.gen / t_gen:.1f} tok/s)")
    print("sample tokens:", np.asarray(gen[0, :16]).tolist())


if __name__ == "__main__":
    main()
