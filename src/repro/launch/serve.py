"""Serving launcher: batched greedy decode against a KV cache.

With ``--tensor-parallel N --tuning-table ART`` the decode loop runs the
tuned tensor-parallel path: every token's logits assembly goes through the
`Communicator`'s {algorithm, segments} choice for the all-gather
(vocab-parallel shards) or all-reduce (partial sums) — bit-identical to
the untuned loop, but executing the tuned wire schedule. The printed
decode plan is `Communicator.explain` over the same requests the step
executes. ``--probe-fabric`` probes the live fabric first so a
multi-backend artifact resolves to the matching profile's table.

Examples:
    python -m repro.launch.serve --arch smollm-135m --reduced \\
        --prompt-len 32 --gen 32 --batch 4
    XLA_FLAGS=--xla_force_host_platform_device_count=2 \\
        python -m repro.launch.serve --arch smollm-135m --reduced \\
        --tensor-parallel 2 --tuning-table tuned_decision.json
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHITECTURES
from repro.models.registry import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m",
                    choices=sorted(ARCHITECTURES))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--window", type=int, default=0)
    ap.add_argument("--tuning-table", default=None,
                    help="tuned decision artifact (schema 2 or 3); prints "
                         "the tuned collective plan and, with "
                         "--tensor-parallel, drives the decode loop's "
                         "logits collective through it")
    ap.add_argument("--tensor-parallel", type=int, default=0,
                    help=">=2: run the tuned TP decode path over a 'model' "
                         "mesh axis of this size (requires --tuning-table "
                         "and that many devices)")
    ap.add_argument("--tp-collective", default="all_gather",
                    choices=("all_gather", "all_reduce"),
                    help="which tuned collective assembles the TP logits")
    ap.add_argument("--probe-fabric", action="store_true",
                    help="probe the live fabric before selecting a table "
                         "from a multi-backend artifact (instead of "
                         "first-table-wins)")
    ap.add_argument("--trace-dir", default=None,
                    help="write decode_summary.json here (per-token "
                         "latency percentiles + throughput + config)")
    args = ap.parse_args()

    cfg = ARCHITECTURES[args.arch]
    if args.reduced:
        cfg = cfg.reduced()

    from repro.comms import Communicator
    comm = None
    if args.tuning_table:
        from repro.launch.tp_decode import tp_decode_plan
        # the launch's single Communicator: probe -> select -> decide ->
        # dispatch (serving only dispatches with --tensor-parallel, but
        # the plan below is resolved through the same object)
        comm = Communicator.create(artifact=args.tuning_table,
                                   probe=args.probe_fabric)
        print(f"tuning table: {args.tuning_table} ({comm.describe()})")
        # decode-time collectives: per-token TP all-reduce of the residual
        # (B, d) and all-gather of vocab-parallel logits (B, V/p)
        p = args.tensor_parallel or max(jax.device_count(), 2)
        print(f"  decode plan p={p}")
        print(tp_decode_plan(comm, args.batch, cfg.d_model,
                             cfg.vocab_size, p).render(indent="    "))
    api = build_model(cfg, window=args.window,
                      attn_impl="xla" if jax.default_backend() != "tpu"
                      else "auto")
    params = api.init(jax.random.PRNGKey(0))
    B = args.batch
    cache_len = args.prompt_len + args.gen

    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                      (B, args.prompt_len)), jnp.int32)

    if args.tensor_parallel >= 2:
        if comm is None:
            raise SystemExit("--tensor-parallel needs --tuning-table")
        from repro import compat
        from repro.launch.tp_decode import build_tp_decode_step, executed_spec
        tp = args.tensor_parallel
        if jax.device_count() < tp:
            raise SystemExit(f"{tp}-way tensor parallelism needs {tp} "
                             f"devices, have {jax.device_count()} (set "
                             "XLA_FLAGS=--xla_force_host_platform_device_"
                             f"count={tp})")
        tp_mesh = compat.make_mesh((tp,), ("model",))
        step = build_tp_decode_step(api, tp_mesh, comm,
                                    collective=args.tp_collective)
        nbytes, spec = executed_spec(comm, args.tp_collective,
                                     args.batch, cfg.vocab_size, tp)
        print(f"tensor-parallel decode: p={tp} via tuned "
              f"{args.tp_collective} ({nbytes} B -> {spec.algorithm} "
              f"segments={spec.segments})")
    else:
        step = jax.jit(api.decode_step)
    cache = api.init_cache(B, cache_len)

    # prefill by stepping the prompt (uniform across families)
    t0 = time.time()
    tok = prompt[:, :1]
    for i in range(args.prompt_len):
        logits, cache = step(params, cache, prompt[:, i:i + 1])
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    out = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    # per-token latency: each token is synced before the next issues, so
    # the percentiles are honest tail latencies (the number a serving
    # SLO watches), not async dispatch times
    tok_ms = []
    t0 = time.time()
    for _ in range(args.gen):
        out.append(tok)
        tt0 = time.perf_counter()
        logits, cache = step(params, cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        jax.block_until_ready(tok)
        tok_ms.append((time.perf_counter() - tt0) * 1e3)
    t_gen = time.time() - t0

    gen = jnp.concatenate(out, axis=1)
    p50, p90, p99 = np.percentile(tok_ms, [50, 90, 99])
    print(f"arch={cfg.name} batch={B} prompt={args.prompt_len} "
          f"gen={args.gen}")
    print(f"prefill: {t_prefill:.2f}s  decode: {t_gen:.2f}s "
          f"({B * args.gen / t_gen:.1f} tok/s)")
    print(f"per-token decode latency: p50 {p50:.2f} ms  "
          f"p90 {p90:.2f} ms  p99 {p99:.2f} ms")
    print("sample tokens:", np.asarray(gen[0, :16]).tolist())

    if args.trace_dir:
        import os

        from repro.obs import export as obs_export
        os.makedirs(args.trace_dir, exist_ok=True)
        obs_export.write_summary(
            os.path.join(args.trace_dir, "decode_summary.json"),
            counters=comm.metrics if comm is not None else None,
            extra={"arch": cfg.name, "batch": B,
                   "prompt_len": args.prompt_len, "gen": args.gen,
                   "tensor_parallel": args.tensor_parallel,
                   "prefill_s": t_prefill, "decode_s": t_gen,
                   "tok_per_s": B * args.gen / t_gen,
                   "token_ms_p50": float(p50),
                   "token_ms_p90": float(p90),
                   "token_ms_p99": float(p99)})
        print(f"decode summary -> {args.trace_dir}/decode_summary.json")


if __name__ == "__main__":
    main()
