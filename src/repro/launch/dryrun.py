import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production mesh with ShapeDtypeStruct stand-ins (no allocation), then
record memory/cost/collective analysis for EXPERIMENTS.md.

Usage:
    python -m repro.launch.dryrun --arch glm4-9b --shape train_4k
    python -m repro.launch.dryrun --arch glm4-9b --shape train_4k --multipod
    python -m repro.launch.dryrun --all          # every combo, single-pod

The XLA_FLAGS line above MUST stay the first statement — jax locks the
device count at first init. Only this entrypoint sees 512 host devices.
"""
import argparse
import json
import time
import traceback

import jax

from repro import compat
from repro.configs import ARCHITECTURES, SHAPES, CollectiveConfig, ParallelConfig
from repro.launch import hlo_analysis as ha
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step, serve_plan


def parallel_for(arch: str, shape_kind: str) -> ParallelConfig:
    # FSDP for the archs whose optimizer state cannot replicate over data;
    # arctic's 480B params don't fit 16 GB/chip even at serve time with
    # model-axis sharding alone, so its weights shard over data always.
    big = arch in ("arctic-480b", "glm4-9b", "chatglm3-6b",
                   "llava-next-mistral-7b", "qwen2.5-3b", "olmoe-1b-7b",
                   "whisper-large-v3", "zamba2-2.7b")
    fsdp = (big and shape_kind == "train") or arch == "arctic-480b"
    return ParallelConfig(
        shard_params_over_data=fsdp,
        remat="full" if shape_kind == "train" else "none",
    )


def _acct_cfg(cfg, units: int):
    """Config with ``units`` homogeneous layer-units (hybrid unit = one
    mamba group + shared attention application; encdec unit = one encoder +
    one decoder layer)."""
    if cfg.family == "hybrid":
        return cfg.replace(num_layers=units * cfg.attn_every)
    if cfg.family == "encdec":
        return cfg.replace(num_layers=units, encoder_layers=units)
    return cfg.replace(num_layers=units)


def _units(cfg) -> int:
    if cfg.family == "hybrid":
        return cfg.num_layers // cfg.attn_every
    return cfg.num_layers


def accounting_metrics(cfg, shape, parallel, coll, mesh, **kw) -> dict:
    """Loop-corrected flops / bytes / collective-bytes.

    XLA's HloCostAnalysis counts while-loop bodies once, so the production
    (scanned) program under-reports everything inside the layer loop. We
    lower an UNROLLED variant at 1 and 2 layer-units — per-unit cost
    B = f(2) - f(1) — and extrapolate: corrected = f(1) + (U - 1) * B.
    """
    def measure(units: int) -> dict:
        c = _acct_cfg(cfg, units)
        fn, args, in_sh, out_sh, _ = build_step(c, shape, parallel, coll,
                                                mesh, accounting=True, **kw)
        compiled = jax.jit(fn, in_shardings=in_sh,
                           out_shardings=out_sh).lower(*args).compile()
        cost = compat.cost_analysis(compiled)
        coll_b = ha.collective_bytes(compiled.as_text())
        return {
            "flops": float(cost.get("flops", 0)),
            "bytes": float(cost.get("bytes accessed", 0)),
            "coll": coll_b,
        }

    f1 = measure(1)
    f2 = measure(2)
    U = _units(cfg)

    def extrap(a, b):
        return a + (U - 1) * (b - a)

    coll = {k: max(0.0, extrap(f1["coll"][k], f2["coll"][k]))
            for k in f1["coll"]}
    return {
        "flops": max(0.0, extrap(f1["flops"], f2["flops"])),
        "bytes": max(0.0, extrap(f1["bytes"], f2["bytes"])),
        "coll": coll,
        "per_unit_flops": f2["flops"] - f1["flops"],
        "units": U,
    }


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            coll_algorithm: str = "xla", a2a_algorithm: str = "xla",
            shard_cache_seq: bool = False, bf16_gather: bool = False,
            seq_shard: bool = True, ssm_chunk: int = 0,
            out_dir: str = "experiments/dryrun") -> dict:
    cfg = ARCHITECTURES[arch]
    if ssm_chunk:
        cfg = cfg.replace(ssm_chunk=ssm_chunk)
    shape = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "collective": coll_algorithm, "a2a": a2a_algorithm,
           "status": "ok"}

    if shape.kind == "decode":
        plan = serve_plan(cfg, shape)
        if not plan.run:
            rec.update(status="skip", reason=plan.reason)
            return rec

    mesh, topology = make_production_mesh(multi_pod=multi_pod)
    rec["topology"] = " > ".join(f"{lv.name}({lv.size})"
                                 for lv in reversed(topology.levels))
    chips = mesh.size
    parallel = parallel_for(arch, shape.kind)
    import dataclasses as _dc
    if bf16_gather:
        parallel = _dc.replace(parallel, gather_in_compute_dtype=True)
    if not seq_shard:
        parallel = _dc.replace(parallel, seq_shard_activations=False)
    coll = CollectiveConfig(algorithm=coll_algorithm,
                            a2a_algorithm=a2a_algorithm)

    kw = {}
    if shape.kind == "decode":
        kw["shard_cache_seq"] = shard_cache_seq
    t0 = time.time()
    fn, args, in_sh, out_sh, donate = build_step(cfg, shape, parallel, coll,
                                                 mesh, **kw)
    jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=donate)
    lowered = jitted.lower(*args)
    rec["lower_s"] = round(time.time() - t0, 1)

    t0 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 1)

    mem = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes_per_device": int(mem.argument_size_in_bytes),
        "output_bytes_per_device": int(mem.output_size_in_bytes),
        "temp_bytes_per_device": int(mem.temp_size_in_bytes),
        "alias_bytes_per_device": int(mem.alias_size_in_bytes),
    }
    peak = (mem.argument_size_in_bytes + mem.output_size_in_bytes
            + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
    rec["memory"]["peak_bytes_per_device"] = int(peak)
    rec["fits_16gb_hbm"] = bool(peak < 16e9)

    cost = compat.cost_analysis(compiled)
    txt = compiled.as_text()
    coll_b = ha.collective_bytes(txt)
    rec["cost_raw"] = {"flops": float(cost.get("flops", 0)),
                       "bytes_accessed": float(cost.get("bytes accessed", 0))}
    rec["collective_bytes_raw"] = coll_b

    # loop-corrected accounting (unrolled 1/2-unit lowering, extrapolated)
    t0 = time.time()
    try:
        acct = accounting_metrics(cfg, shape, parallel, coll, mesh, **kw)
        rec["accounting_s"] = round(time.time() - t0, 1)
        cost_c = {"flops": acct["flops"], "bytes accessed": acct["bytes"]}
        coll_c = {k: int(v) for k, v in acct["coll"].items()}
        rec["cost"] = {"flops": acct["flops"],
                       "bytes_accessed": acct["bytes"],
                       "per_unit_flops": acct["per_unit_flops"],
                       "units": acct["units"]}
        rec["collective_bytes"] = coll_c
    except Exception as e:  # fall back to the raw (undercounted) numbers
        rec["accounting_error"] = f"{type(e).__name__}: {e}"
        cost_c, coll_c = cost, coll_b
        rec["cost"] = rec["cost_raw"]
        rec["collective_bytes"] = coll_b

    mf = ha.model_flops(cfg, shape)
    roof = ha.roofline(cost_c, coll_c, chips=chips, model_flops_global=mf)
    rec["roofline"] = roof.as_dict()

    os.makedirs(out_dir, exist_ok=True)
    tag = f"{arch}_{shape_name}_{rec['mesh']}_{coll_algorithm}"
    if a2a_algorithm != "xla":
        tag += f"_a2a-{a2a_algorithm}"
    if shard_cache_seq:
        tag += "_seqshard"
    if bf16_gather:
        tag += "_bf16gather"
    if not seq_shard:
        tag += "_noseqshard"
    if ssm_chunk:
        tag += f"_chunk{ssm_chunk}"
    with open(os.path.join(out_dir, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHITECTURES))
    ap.add_argument("--shape", choices=sorted(SHAPES))
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--collective", default="xla")
    ap.add_argument("--a2a", default="xla")
    ap.add_argument("--shard-cache-seq", action="store_true")
    ap.add_argument("--bf16-gather", action="store_true")
    ap.add_argument("--no-seq-shard", action="store_true")
    ap.add_argument("--ssm-chunk", type=int, default=0)
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    combos = ([(a, s) for a in sorted(ARCHITECTURES) for s in SHAPES]
              if args.all else [(args.arch, args.shape)])
    failures = 0
    for arch, shape in combos:
        try:
            rec = run_one(arch, shape, multi_pod=args.multipod,
                          coll_algorithm=args.collective,
                          a2a_algorithm=args.a2a,
                          shard_cache_seq=args.shard_cache_seq,
                          bf16_gather=args.bf16_gather,
                          seq_shard=not args.no_seq_shard,
                          ssm_chunk=args.ssm_chunk,
                          out_dir=args.out)
            roof = rec.get("roofline", {})
            print(f"[{rec['status']:4s}] {arch:24s} {shape:12s} "
                  f"{rec['mesh']:8s} "
                  f"peak={rec.get('memory', {}).get('peak_bytes_per_device', 0) / 1e9:6.2f}GB "
                  f"dom={roof.get('dominant', '-'):10s} "
                  f"(lower {rec.get('lower_s', 0)}s, "
                  f"compile {rec.get('compile_s', 0)}s)"
                  + (f" SKIP: {rec.get('reason', '')[:60]}"
                     if rec["status"] == "skip" else ""),
                  flush=True)
        except Exception as e:
            failures += 1
            print(f"[FAIL] {arch} {shape}: {type(e).__name__}: {e}",
                  flush=True)
            traceback.print_exc()
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
