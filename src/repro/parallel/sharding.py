"""Per-architecture parameter/batch/cache PartitionSpecs.

Megatron-style tensor parallel on the ``model`` axis (attention heads +
FFN hidden), optional ZeRO-3/FSDP on the data axes, expert parallel for MoE,
all guarded by divisibility checks — head counts like smollm's 9 or
whisper's 20 don't divide a 16-way axis, in which case that tensor stays
replicated on the model axis and (where possible) shards on the data axes
instead. These fallbacks are recorded per-arch in EXPERIMENTS.md §Dry-run.
"""
from __future__ import annotations

from typing import Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelConfig, ShapeConfig


def dp_axes(mesh) -> Tuple[str, ...]:
    """Data-parallel mesh axes, outermost first ("dcn" across the WAN
    links, "pod" across pods, "data" inside)."""
    return tuple(a for a in ("dcn", "pod", "data") if a in mesh.axis_names)


def dp_size(mesh) -> int:
    s = 1
    for a in dp_axes(mesh):
        s *= mesh.shape[a]
    return s


def model_size(mesh) -> int:
    return mesh.shape.get("model", 1)


def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def param_specs(params_tree, cfg: ModelConfig, parallel: ParallelConfig,
                mesh):
    """PartitionSpec pytree matching ``params_tree`` (arrays or structs)."""
    tp = model_size(mesh)
    dpx = dp_axes(mesh)
    dsz = dp_size(mesh)
    fsdp_on = parallel.shard_params_over_data

    def fsdp(dim: int):
        return dpx if (fsdp_on and _div(dim, dsz)) else None

    def mdl(dim: int):
        return "model" if _div(dim, tp) and tp > 1 else None

    def rule(path, leaf):
        name = _path_str(path)
        shape = leaf.shape
        last = name.rsplit("/", 1)[-1]
        stacked = name.startswith("layers") or "/encoder/" in name \
            or "/decoder/" in name or name.startswith("encoder") \
            or name.startswith("decoder")
        off = 1 if (stacked and len(shape) >= 2) else 0

        def spec(*entries):
            lead = (None,) * off
            ent = lead + entries
            ent = ent + (None,) * (len(shape) - len(ent))
            return P(*ent[:len(shape)])

        if last in ("tok",):
            return P(mdl(shape[0]), fsdp(shape[1]))
        if last == "out":
            return P(fsdp(shape[0]), mdl(shape[1]))
        if last in ("pos", "enc_pos", "final_norm"):
            return P()
        if last in ("wq", "wk", "wv"):            # (L, d, H, Dh)
            return spec(fsdp(shape[off]), mdl(shape[off + 1]), None)
        if last in ("bq", "bk", "bv"):            # (L, H, Dh)
            return spec(mdl(shape[off]), None)
        if last == "wo":                          # (L, H, Dh, d)
            return spec(mdl(shape[off]), None, fsdp(shape[off + 2]))
        if last in ("w_gate", "w_up", "w_down"):
            if len(shape) - off == 3:             # MoE expert (L, E, d, ff)
                if last == "w_down":
                    return spec(mdl(shape[off]), None, fsdp(shape[off + 2]))
                return spec(mdl(shape[off]), fsdp(shape[off + 1]), None)
            if last == "w_down":                  # (L, ff, d)
                return spec(mdl(shape[off]), fsdp(shape[off + 1]))
            return spec(fsdp(shape[off]), mdl(shape[off + 1]))
        if last == "router":                      # (L, d, E)
            return spec(fsdp(shape[off]), None)
        if last == "in_proj":                     # ssm (L, d, proj)
            return spec(fsdp(shape[off]), None)
        if last == "out_proj":                    # ssm (L, d_inner, d)
            return spec(fsdp(shape[off]), None)
        if last in ("conv_w", "conv_b", "A_log", "D", "dt_bias", "norm",
                    "ln", "ln1", "ln2", "ln3", "scale", "bias"):
            return P(*(None,) * len(shape))
        # default: replicate
        return P(*(None,) * len(shape))

    return jax.tree_util.tree_map_with_path(rule, params_tree)


def ep_param_specs(params_tree, ep_axis: str):
    """shard_map in_specs for parameters entering the ONE manual program
    with expert parallelism riding the manual region: stacked MoE expert
    weights (L, E, ...) split over ``ep_axis`` on the E dim — matching
    their storage sharding (`param_specs`' mdl(E) rule), so entering the
    manual region moves no bytes — everything else replicated (the
    attention/embedding compute is replicated over the model ranks
    inside manual, exactly like the 0.4.x full-manual fallback)."""
    def rule(path, leaf):
        last = _path_str(path).rsplit("/", 1)[-1]
        if ep_axis and last in ("w_gate", "w_up", "w_down") \
                and len(leaf.shape) == 4:         # MoE expert (L, E, d, ff)
            return P(None, ep_axis, None, None)
        return P()

    return jax.tree_util.tree_map_with_path(rule, params_tree)


def batch_specs(batch_tree, mesh, shape_cfg: ShapeConfig):
    dpx = dp_axes(mesh)
    dsz = dp_size(mesh)

    def rule(path, leaf):
        b = leaf.shape[0]
        lead = dpx if _div(b, dsz) else None
        return P(lead, *(None,) * (len(leaf.shape) - 1))

    return jax.tree_util.tree_map_with_path(rule, batch_tree)


def cache_specs(cache_tree, cfg: ModelConfig, mesh, *,
                shard_cache_seq: bool = False):
    """KV caches: batch over data axes; kv-heads over model when divisible;
    optionally the sequence dim over model (flash-decode style, §Perf)."""
    tp = model_size(mesh)
    dpx = dp_axes(mesh)
    dsz = dp_size(mesh)

    def rule(path, leaf):
        name = _path_str(path)
        shape = leaf.shape
        if name.endswith("length") or len(shape) == 0:
            return P()
        if name in ("k", "v", "xk", "xv") or name.endswith("/k") \
                or name.endswith("/v") or name.endswith("xk") \
                or name.endswith("xv"):
            # (L, B, T, KV, Dh)
            bspec = dpx if _div(shape[1], dsz) else None
            kvspec = "model" if (_div(shape[3], tp) and tp > 1
                                 and not shard_cache_seq) else None
            tspec = "model" if (shard_cache_seq and _div(shape[2], tp)
                                and tp > 1) else None
            return P(None, bspec, tspec, kvspec, None)
        if "conv" in name:                        # (L, B, W-1, Cd)
            bspec = dpx if _div(shape[1], dsz) else None
            return P(None, bspec, None, None)
        if "ssd" in name:                         # (L, B, H, N, P)
            bspec = dpx if _div(shape[1], dsz) else None
            hspec = "model" if (_div(shape[2], tp) and tp > 1) else None
            return P(None, bspec, hspec, None, None)
        bspec = dpx if (len(shape) > 1 and _div(shape[1], dsz)) else None
        return P(None, bspec, *(None,) * (len(shape) - 2)) \
            if len(shape) >= 2 else P(*(None,) * len(shape))

    return jax.tree_util.tree_map_with_path(rule, cache_tree)


# ---------------------------------------------------------------------------
# mesh context for in-model sharding constraints
# ---------------------------------------------------------------------------
_CURRENT_MESH = None


def set_current_mesh(mesh):
    global _CURRENT_MESH
    _CURRENT_MESH = mesh


def current_mesh():
    return _CURRENT_MESH


#: trace-time override for JAX versions without ``get_abstract_mesh``
#: (0.4.x): compat.shard_map registers its manual axes here while tracing
_MANUAL_OVERRIDE: set = set()


def set_manual_override(axes):
    """Declare mesh axes as under manual shard_map control (legacy JAX).
    Returns the previous value for restore."""
    global _MANUAL_OVERRIDE
    prev = _MANUAL_OVERRIDE
    _MANUAL_OVERRIDE = set(axes)
    return prev


def _manual_axes():
    """Axis names currently under shard_map manual control (partial-manual
    regions): constraints must not mention them — those dims are already
    local there."""
    try:
        am = jax.sharding.get_abstract_mesh()
    except Exception:
        return set(_MANUAL_OVERRIDE), None
    if am is None or not am.axis_names:
        return set(_MANUAL_OVERRIDE), None
    manual = {n for n, t in zip(am.axis_names, am.axis_types)
              if "Manual" in str(t)}
    return manual, am


def _constrain(x, entries):
    mesh = _CURRENT_MESH
    if mesh is None:
        return x
    manual, am = _manual_axes()

    def filt(e):
        if e is None:
            return None
        if isinstance(e, tuple):
            kept = tuple(a for a in e if a not in manual)
            return kept or None
        return None if e in manual else e

    entries = tuple(filt(e) for e in entries)
    if manual and am is None:
        # legacy JAX inside (full-)manual shard_map: no abstract mesh to
        # constrain against; the surviving entries are hints only — drop them
        return x
    target = am if manual else mesh
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(target, P(*entries)))


def constrain_logits(x):
    """(B, S, V): batch over data axes, vocab over model (Megatron
    vocab-parallel loss) — keeps the (tokens x vocab) tensor sharded both
    ways through the softmax/CE."""
    mesh = _CURRENT_MESH
    if mesh is None:
        return x
    tp = model_size(mesh)
    dpx = dp_axes(mesh)
    b = dpx if _div(x.shape[0], dp_size(mesh)) else None
    v = "model" if (_div(x.shape[-1], tp) and tp > 1) else None
    return _constrain(x, (b, None, v))


def constrain_activations(x):
    """(B, S, d): batch over data axes."""
    mesh = _CURRENT_MESH
    if mesh is None:
        return x
    b = dp_axes(mesh) if _div(x.shape[0], dp_size(mesh)) else None
    return _constrain(x, (b,) + (None,) * (x.ndim - 1))


_SEQ_SHARD = True


def set_seq_sharding(on: bool):
    """Megatron sequence parallelism for the residual stream."""
    global _SEQ_SHARD
    _SEQ_SHARD = on


def constrain_residual(x):
    """Residual stream (B, S, d) between blocks: batch over data axes,
    sequence over the model axis (sequence parallelism). Pinning this inside
    the layer scan (a) keeps per-layer remat residuals 1/tp-sized and
    (b) stops XLA from resolving FSDP sharding conflicts by replicating
    activations over the data axes."""
    mesh = _CURRENT_MESH
    if mesh is None or x.ndim != 3:
        return x
    tp = model_size(mesh)
    b = dp_axes(mesh) if _div(x.shape[0], dp_size(mesh)) else None
    s = "model" if (_SEQ_SHARD and tp > 1 and _div(x.shape[1], tp)
                    and x.shape[1] > 1) else None
    return _constrain(x, (b, s, None))


def to_named(spec_tree, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
