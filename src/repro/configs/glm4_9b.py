"""glm4-9b [dense] — RoPE (partial), GQA kv=2. [hf:THUDM/glm-4-9b]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b",
    family="dense",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab_size=151552,
    rotary_pct=0.5,  # GLM applies rotary to half the head dim ("2d" RoPE family)
    rope_theta=10000.0,
    qkv_bias=True,   # GLM-4 uses bias on QKV only
    source="hf:THUDM/glm-4-9b",
)
