"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention blocks. [arXiv:2411.15242]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,           # mamba2 blocks
    d_model=2560,
    num_heads=32,            # the shared attention block (GQA kv=32 i.e. MHA)
    num_kv_heads=32,
    head_dim=80,
    d_ff=10240,              # shared block MLP
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,
    expand=2,
    attn_every=6,            # shared attention block interleaved every 6 mamba blocks
    source="arXiv:2411.15242",
)
