"""whisper-large-v3 [audio] — enc-dec transformer backbone; conv/mel frontend is a
stub supplying precomputed frame embeddings. [arXiv:2212.04356]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="encdec",
    num_layers=32,           # decoder layers
    encoder_layers=32,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    learned_pos=True,
    encoder_seq=1500,        # 30 s of audio at 50 Hz after the (stubbed) conv frontend
    source="arXiv:2212.04356",
)
