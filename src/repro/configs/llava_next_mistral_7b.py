"""llava-next-mistral-7b [vlm] — Mistral-7B language backbone consuming precomputed
anyres patch embeddings (vision tower + projector stubbed).
[hf:llava-hf/llava-v1.6-mistral-7b-hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    rope_theta=1000000.0,
    num_patches=2880,        # anyres tiling: 5 tiles x 576 patch tokens (stub)
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)
