"""mamba2-130m [ssm] — SSD (state-space duality), attention-free. [arXiv:2405.21060]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=0,             # attention-free
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,         # d_inner=1536 -> 24 SSD heads
    expand=2,
    source="arXiv:2405.21060",
)
