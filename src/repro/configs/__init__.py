"""Architecture registry: ``get_config("<arch-id>")`` and the four shapes."""
from repro.configs.base import (
    CollectiveConfig,
    ModelConfig,
    ParallelConfig,
    ShapeConfig,
    TrainConfig,
)
from repro.configs.shapes import SHAPES, get_shape

from repro.configs import (  # noqa: E402
    arctic_480b,
    chatglm3_6b,
    glm4_9b,
    llava_next_mistral_7b,
    mamba2_130m,
    olmoe_1b_7b,
    qwen2p5_3b,
    smollm_135m,
    whisper_large_v3,
    zamba2_2p7b,
)

ARCHITECTURES = {
    m.CONFIG.name: m.CONFIG
    for m in (
        glm4_9b,
        smollm_135m,
        zamba2_2p7b,
        whisper_large_v3,
        olmoe_1b_7b,
        chatglm3_6b,
        mamba2_130m,
        llava_next_mistral_7b,
        qwen2p5_3b,
        arctic_480b,
    )
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHITECTURES:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHITECTURES)}")
    return ARCHITECTURES[name]


__all__ = [
    "ARCHITECTURES",
    "CollectiveConfig",
    "ModelConfig",
    "ParallelConfig",
    "ShapeConfig",
    "SHAPES",
    "TrainConfig",
    "get_config",
    "get_shape",
]
