"""Config dataclasses for models, parallelism and tuned collectives.

Every assigned architecture is a frozen `ModelConfig`; input shapes are
`ShapeConfig`s; the paper's technique enters through `CollectiveConfig`,
which names the {algorithm, segment size} decision source used by the
distributed runtime.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Optional, Union

if TYPE_CHECKING:
    from repro.core.tuning.decision import DecisionTable


@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters (transformer backbone scope only)."""

    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    source: str = ""   # citation for the config

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    dense_residual: bool = False  # arctic: dense FFN in parallel with MoE
    dense_d_ff: int = 0
    capacity_factor: float = 1.25

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    d_conv: int = 4
    expand: int = 2

    # --- hybrid (zamba2) ---
    attn_every: int = 0  # apply the shared attention block every N ssm blocks

    # --- position / attention flavour ---
    rope_theta: float = 10000.0
    rotary_pct: float = 1.0  # chatglm/glm4 use partial ("2d") rotary
    qkv_bias: bool = False
    learned_pos: bool = False  # whisper
    sliding_window: int = 0    # 0 = full attention (training default)

    # --- enc-dec (whisper backbone) ---
    encoder_layers: int = 0
    encoder_seq: int = 0  # fixed source frame count (precomputed conv features)

    # --- VLM (llava) ---
    num_patches: int = 0  # precomputed anyres patch-embedding count (stub frontend)

    max_positions: int = 4096  # learned-pos table size (whisper decoder)
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Can serve 500k-token contexts (SSM state or sliding window)."""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: <=2 layers, d_model<=512, <=4 experts."""
        d_model = min(self.d_model, 256)
        num_heads = min(self.num_heads, 4)
        head_dim = min(self.resolved_head_dim, 64)
        num_kv = max(1, min(self.num_kv_heads, num_heads))
        # keep the GQA ratio when possible
        if self.num_kv_heads < self.num_heads:
            num_kv = max(1, num_heads // max(1, self.num_heads // self.num_kv_heads))
        kw = dict(
            num_layers=2,
            d_model=d_model,
            num_heads=num_heads,
            num_kv_heads=num_kv,
            head_dim=head_dim,
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 1024),
        )
        if self.num_experts:
            kw.update(num_experts=4, experts_per_token=min(2, self.experts_per_token))
        if self.dense_d_ff:
            kw.update(dense_d_ff=min(self.dense_d_ff, 512))
        if self.ssm_state:
            kw.update(ssm_state=min(self.ssm_state, 16), ssm_chunk=32)
        if self.attn_every:
            kw.update(attn_every=1)
        if self.encoder_layers:
            kw.update(encoder_layers=2, encoder_seq=min(self.encoder_seq, 64))
        if self.num_patches:
            kw.update(num_patches=16)
        return self.replace(**kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One of the four assigned input shapes."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


@dataclass(frozen=True)
class CollectiveConfig:
    """How collectives are implemented/tuned — the paper's technique.

    algorithm: "xla" uses the compiler's lowering (baseline, = MPI's
    hardcoded default in the survey); otherwise one of the registered
    shard_map algorithm names ("ring", "recursive_halving", ...).
    segment_bytes: 0 = unsegmented.
    decision: optional tuned DecisionTable that overrides the static fields
    per (op, bytes, axis size) — either a path to the serialized JSON
    artifact or an already-loaded DecisionTable instance.
    """

    algorithm: str = "xla"
    segment_bytes: int = 0
    decision: Optional[Union[str, "DecisionTable"]] = None
    a2a_algorithm: str = "xla"     # MoE expert-dispatch all-to-all algorithm
    overlap_microbatches: int = 1  # >1 enables comm/compute overlap (§4.1)
    bucket_bytes: Optional[int] = None  # fusion-bucket budget for the
    # bucketed, overlap-pipelined gradient sync; None = adopt the
    # artifact's tuned schedule (sequential per-leaf when it carries
    # none), 0 = force the per-leaf path even over a schedule-carrying
    # artifact
    overlap_backward: bool = False  # backward-overlapped streamed sync:
    # per-layer custom_vjp release points issue each layer's tier-0
    # reduce-scatter during backward compute (unrolls the layer stack;
    # --overlap-backward on the train CLI)


class CollectiveConfigError(ValueError):
    """An unsupported collective-config combination, detected at
    config/CLI parse time (not mid-trace) with the flags to change."""


def validate_collectives(coll: "CollectiveConfig",
                         parallel: "ParallelConfig",
                         tuned: Optional[bool] = None) -> None:
    """Reject collective/parallel combinations the step builder cannot
    execute, naming the flags that conflict. ``tuned`` is whether the
    resolved communicator takes the explicit tuned-sync path (defaults
    to what the config alone implies: a non-xla algorithm, a decision
    artifact, or a fusion-bucket budget)."""
    if tuned is None:
        tuned = (coll.algorithm != "xla" or coll.decision is not None
                 or bool(coll.bucket_bytes))
    if tuned and parallel.shard_params_over_data:
        raise CollectiveConfigError(
            "tuned gradient sync and FSDP param sharding are mutually "
            "exclusive (DESIGN.md §3): tuned sync all-reduces full "
            "gradients inside shard_map, FSDP reduce-scatters per-shard. "
            "Drop --fsdp (ParallelConfig.shard_params_over_data) or run "
            "the XLA path (--collective xla, no --tuning-table / "
            "--bucket-mb).")
    if coll.overlap_backward and parallel.shard_params_over_data:
        raise CollectiveConfigError(
            "--overlap-backward requires non-FSDP params: release points "
            "sync full per-layer gradients, FSDP shards them. Drop "
            "--fsdp (ParallelConfig.shard_params_over_data) or "
            "--overlap-backward.")
    if coll.overlap_backward and not tuned:
        raise CollectiveConfigError(
            "--overlap-backward needs the tuned gradient-sync path to "
            "issue release-point collectives: pass --tuning-table, "
            "--collective <algorithm>, or --bucket-mb (the plain XLA "
            "path has no explicit sync to overlap).")
    if coll.overlap_backward and coll.overlap_microbatches > 1:
        raise CollectiveConfigError(
            "--overlap-backward and --overlap-microbatches are mutually "
            "exclusive: release points would sync partial gradients once "
            "per microbatch (k x the communication). Set "
            "--overlap-microbatches 1 or drop --overlap-backward.")


@dataclass(frozen=True)
class ParallelConfig:
    data_axes: tuple = ("data",)   # ("pod","data") on multi-pod meshes
    model_axis: str = "model"
    remat: str = "none"            # none | full | selective
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # beyond-paper knobs exercised during hillclimbing:
    shard_params_over_data: bool = False  # ZeRO-3 style (FSDP) param sharding
    seq_shard_activations: bool = True    # shard long sequences over "model"
    gather_in_compute_dtype: bool = False  # cast fp32 master params to bf16
    # BEFORE the FSDP all-gather (halves gather bytes; grads still fp32)


@dataclass(frozen=True)
class TrainConfig:
    model: ModelConfig
    shape: ShapeConfig
    parallel: ParallelConfig = field(default_factory=ParallelConfig)
    collectives: CollectiveConfig = field(default_factory=CollectiveConfig)
    lr: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    seed: int = 0
