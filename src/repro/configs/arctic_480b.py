"""arctic-480b [moe] — 128 experts top-2 with dense residual MLP.
[hf:Snowflake/snowflake-arctic-base]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=4864,               # per-expert FFN width
    vocab_size=32000,
    num_experts=128,
    experts_per_token=2,
    dense_residual=True,     # dense MLP in parallel with the MoE (Arctic design)
    dense_d_ff=4864,
    source="hf:Snowflake/snowflake-arctic-base",
)
